"""Batched mapping evaluation.

Default engine: the **universal** structure-as-operand evaluator
(``mapspace.universal``) — one jit+vmap executable per (op, level-count)
whose operands encode the entire mapping (tile sizes, permutation rank,
spatial one-hot, cluster option, hardware point).  A mapping space costs at
most TWO compiles no matter how many (spatial × perm × cluster) structure
groups the evaluated points span.

The legacy **grouped** engine (one executable per structure group, tile
sizes as the only operands) is kept behind ``engine="grouped"`` as a
cross-check and for spaces outside the universal family.  Batches are
padded to a fixed block so each executable compiles exactly once per
(block, structure) shape; timing separates that one-off compile from the
steady-state evaluation the mappings/s rate is quoted on.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np
import jax.numpy as jnp

from .. import obs
from ..core.tensor_analysis import LayerOp
from ..core.vectorized import FEATURES, batched_tile_evaluator
from ..resilience import default_policy, fault_point, run_attempts
from .space import GroupKey, MapSpace, Point, group_template, point_operands
from .universal import evaluate_points_universal

# Column indices into the feature matrix, re-exported for consumers.
FEATURE_INDEX = {name: i for i, name in enumerate(FEATURES)}

# Grouped-engine executables already warmed at a given block shape this
# process, keyed by the deterministic (op, template, hardware, block) tuple
# — NOT id(f), which the interpreter may reuse after the evaluator
# lru_cache evicts an entry, misclassifying a fresh multi-second compile as
# a steady-state call.
_WARMED: set[tuple] = set()


def _warm_key(op: LayerOp, template_name: str, var_slots, num_pes,
              noc_bw, multicast, spatial_reduction, block: int) -> tuple:
    return (op.name, tuple(sorted(op.dims.items())), op.op_type,
            template_name, tuple(var_slots), int(num_pes), float(noc_bw),
            bool(multicast), bool(spatial_reduction), block)


@dataclasses.dataclass
class EvalStats:
    """Bookkeeping for one evaluate_points call.

    ``mappings_per_s`` is THE steady-state rate definition shared by every
    consumer (``SearchResult`` delegates here): rows actually evaluated in
    steady-timed calls (padding rows excluded, first-call compile re-runs
    excluded) divided by the steady evaluation time."""
    n_points: int = 0
    n_groups: int = 0
    n_steady: int = 0        # rows evaluated in steady-timed calls
    n_compiles: int = 0      # first-call (XLA compile) executions
    compile_s: float = 0.0   # first call per (executable, block shape)
    eval_s: float = 0.0      # steady-state batched evaluation time
    encode_s: float = 0.0    # host operand-encode time (gene pipeline)

    @property
    def mappings_per_s(self) -> float:
        """Steady-state rate; 0.0 when every call was a first-call compile
        (no steady sample exists)."""
        if not self.n_steady:
            return 0.0
        return self.n_steady / max(self.eval_s, 1e-9)

    def merge(self, other: "EvalStats") -> None:
        self.n_points += other.n_points
        self.n_groups += other.n_groups
        self.n_steady += other.n_steady
        self.n_compiles += other.n_compiles
        self.compile_s += other.compile_s
        self.eval_s += other.eval_s
        self.encode_s += other.encode_s


def evaluate_points(op: LayerOp, space: MapSpace, points: Sequence[Point],
                    *, num_pes: int, noc_bw: float, block: int = 1024,
                    multicast: bool = True, spatial_reduction: bool = True,
                    engine: str = "universal"
                    ) -> tuple[np.ndarray, EvalStats]:
    """Evaluate mappings at a fixed hardware point.

    Returns ``(features[n, F], stats)`` with rows aligned to ``points``
    order.  Points may mix structure groups freely: the universal engine
    needs at most two compiles regardless; the grouped engine regroups
    internally and compiles once per group."""
    if engine == "universal":
        feats, run = evaluate_points_universal(
            op, space, points, num_pes=num_pes, noc_bw=noc_bw,
            block=block, multicast=multicast,
            spatial_reduction=spatial_reduction)
        obs.metrics().inc("mappings.evaluated", len(points))
        groups = {space.group_key(p) for p in points}
        return feats, EvalStats(
            n_points=len(points), n_groups=len(groups),
            n_steady=len(points), n_compiles=run.n_compiles,
            compile_s=run.compile_s, eval_s=run.eval_s)
    if engine != "grouped":
        raise ValueError(f"unknown engine {engine!r}")

    groups: dict[GroupKey, list[int]] = {}
    for i, pt in enumerate(points):
        groups.setdefault(space.group_key(pt), []).append(i)

    feats = np.empty((len(points), len(FEATURES)), np.float32)
    stats = EvalStats(n_points=len(points), n_groups=len(groups))
    for key, idxs in groups.items():
        template, var_slots = group_template(space, key)
        f = batched_tile_evaluator(
            op, template, var_slots, num_pes=num_pes, noc_bw=noc_bw,
            multicast=multicast, spatial_reduction=spatial_reduction)
        sizes, offsets = point_operands(space, [points[i] for i in idxs])
        for lo in range(0, len(idxs), block):
            hi = min(lo + block, len(idxs))
            pad = block - (hi - lo)
            s = np.concatenate([sizes[lo:hi],
                                np.repeat(sizes[lo:lo + 1], pad, 0)]) \
                if pad else sizes[lo:hi]
            o = np.concatenate([offsets[lo:hi],
                                np.repeat(offsets[lo:lo + 1], pad, 0)]) \
                if pad else offsets[lo:hi]
            warm_key = _warm_key(op, template.name, var_slots, num_pes,
                                 noc_bw, multicast, spatial_reduction,
                                 block)
            sj, oj = jnp.asarray(s), jnp.asarray(o)
            if warm_key not in _WARMED:
                # first call at this shape: jit compile — re-run timed so
                # every group contributes a steady-rate sample
                with obs.span("compile", engine="grouped", op=op.name,
                              group=template.name):
                    t0 = time.perf_counter()
                    out = np.asarray(f(sj, oj))
                    dt = time.perf_counter() - t0
                stats.compile_s += dt
                stats.n_compiles += 1
                _WARMED.add(warm_key)
                obs.metrics().inc("grouped.compiles")
                obs.metrics().inc("grouped.compile_s", dt)
            # the grouped engine is the degradation target of the gene
            # pipeline, so its retry site is distinct from "chunk"
            def once():
                fault_point("legacy-batch")
                with obs.span("device-pass", engine="grouped",
                              op=op.name, rows=hi - lo):
                    t0 = time.perf_counter()
                    o_ = np.asarray(f(sj, oj))
                return o_, time.perf_counter() - t0

            out, dt = run_attempts(
                once, policy=default_policy(),
                label=f"{op.name} legacy batch")
            stats.eval_s += dt
            stats.n_steady += hi - lo
            feats[idxs[lo:hi]] = out[:hi - lo]
    obs.metrics().inc("mappings.evaluated", len(points))
    return feats, stats


def measure_rate(op: LayerOp, space: MapSpace, *, num_pes: int,
                 noc_bw: float, block: int = 4096, seconds: float = 2.0,
                 seed: int = 0, group: GroupKey | None = None,
                 multicast: bool = True, spatial_reduction: bool = True,
                 engine: str = "universal") -> float:
    """Steady-state batched evaluation rate (mappings/s) — the number
    comparable to the paper's 0.17M designs/s DSE rate.

    The universal engine times mixed-structure rows sampled uniformly over
    the whole space (or one ``group``); the grouped engine times one
    structure group, as before."""
    rng = np.random.default_rng(seed)
    if engine == "universal":
        from .universal import encode_points, mark_warmed, universal_specs
        from ..core.vectorized import universal_evaluator
        keys = space.group_keys() if group is None else [group]
        pts = []
        for _ in range(block):
            key = keys[int(rng.integers(len(keys)))]
            tiles = tuple(int(rng.integers(ax.n)) for ax in space.axes)
            pts.append(tuple(key) + tiles)
        spec1, spec2 = universal_specs(op, space)
        batches = []
        for spec, sub in (
                (spec1, [p for p in pts
                         if space.cluster_options[p[2]] is None]),
                (spec2, [p for p in pts
                         if space.cluster_options[p[2]] is not None])):
            if not sub:
                continue
            ops = encode_points(op, space, sub, spec,
                                num_pes=num_pes, noc_bw=noc_bw)
            f = universal_evaluator(op, spec, multicast=multicast,
                                    spatial_reduction=spatial_reduction)
            batch = {k: jnp.asarray(v) for k, v in ops.items()}
            # timed batches have their own shape: count the compile so the
            # process-wide O(1)-compile gate sees it
            mark_warmed(op, spec, multicast, spatial_reduction, len(sub))
            f(batch).block_until_ready()   # compile + warm
            batches.append((f, batch))
        n = 0
        t0 = time.perf_counter()
        while time.perf_counter() - t0 < seconds:
            for f, batch in batches:
                f(batch).block_until_ready()
            n += block
        return n / (time.perf_counter() - t0)

    key = group if group is not None else space.group_keys()[0]
    template, var_slots = group_template(space, key)
    f = batched_tile_evaluator(
        op, template, var_slots, num_pes=num_pes, noc_bw=noc_bw,
        multicast=multicast, spatial_reduction=spatial_reduction)
    tiles = np.stack([rng.integers(0, ax.n, block) for ax in space.axes], 1)
    pts = [key + tuple(row) for row in tiles]
    sizes, offsets = point_operands(space, pts)
    s, o = jnp.asarray(sizes), jnp.asarray(offsets)
    f(s, o).block_until_ready()  # compile + warm
    n = 0
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < seconds:
        f(s, o).block_until_ready()
        n += block
    return n / (time.perf_counter() - t0)
