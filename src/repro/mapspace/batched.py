"""Batched mapping evaluation: one jit+vmap executable per structure group.

Candidates sharing a :class:`~repro.mapspace.space.MapSpace` group key
(spatial choice × permutation × cluster option) trace the same iteration-
case structure, so their tile sizes become vmapped operands of a single XLA
computation (``core.vectorized.batched_tile_evaluator``).  Batches are
padded to a fixed block so each group compiles exactly once regardless of
how many candidates the search throws at it; timing separates that one-off
compile from the steady-state evaluation the mappings/s rate is quoted on
(mirroring how ``core.dse`` reports designs/s).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np
import jax.numpy as jnp

from ..core.tensor_analysis import LayerOp
from ..core.vectorized import FEATURES, batched_tile_evaluator
from .space import GroupKey, MapSpace, Point, group_template, point_operands

# Column indices into the feature matrix, re-exported for consumers.
FEATURE_INDEX = {name: i for i, name in enumerate(FEATURES)}

# Executables already warmed at a given block shape this process, keyed by
# the deterministic (op, template, hardware, block) tuple — NOT id(f), which
# the interpreter may reuse after the evaluator lru_cache evicts an entry,
# misclassifying a fresh multi-second compile as a steady-state call.
_WARMED: set[tuple] = set()


def _warm_key(op: LayerOp, template_name: str, var_slots, num_pes,
              noc_bw, multicast, spatial_reduction, block: int) -> tuple:
    return (op.name, tuple(sorted(op.dims.items())), op.op_type,
            template_name, tuple(var_slots), int(num_pes), float(noc_bw),
            bool(multicast), bool(spatial_reduction), block)


@dataclasses.dataclass
class EvalStats:
    """Bookkeeping for one evaluate_points call."""
    n_points: int = 0
    n_groups: int = 0
    n_steady: int = 0        # rows evaluated in steady-timed calls
    compile_s: float = 0.0   # first call per (executable, block shape)
    eval_s: float = 0.0      # steady-state batched evaluation time

    @property
    def mappings_per_s(self) -> float:
        """Steady-state rate; 0.0 when every call was a first-call compile
        (no steady sample exists)."""
        if not self.n_steady:
            return 0.0
        return self.n_steady / max(self.eval_s, 1e-9)

    def merge(self, other: "EvalStats") -> None:
        self.n_points += other.n_points
        self.n_groups += other.n_groups
        self.n_steady += other.n_steady
        self.compile_s += other.compile_s
        self.eval_s += other.eval_s


def evaluate_points(op: LayerOp, space: MapSpace, points: Sequence[Point],
                    *, num_pes: int, noc_bw: float, block: int = 1024,
                    multicast: bool = True, spatial_reduction: bool = True
                    ) -> tuple[np.ndarray, EvalStats]:
    """Evaluate mappings at a fixed hardware point.

    Returns ``(features[n, F], stats)`` with rows aligned to ``points``
    order.  Points are regrouped internally; callers need not pre-sort.
    """
    groups: dict[GroupKey, list[int]] = {}
    for i, pt in enumerate(points):
        groups.setdefault(space.group_key(pt), []).append(i)

    feats = np.empty((len(points), len(FEATURES)), np.float32)
    stats = EvalStats(n_points=len(points), n_groups=len(groups))
    for key, idxs in groups.items():
        template, var_slots = group_template(space, key)
        f = batched_tile_evaluator(
            op, template, var_slots, num_pes=num_pes, noc_bw=noc_bw,
            multicast=multicast, spatial_reduction=spatial_reduction)
        sizes, offsets = point_operands(space, [points[i] for i in idxs])
        for lo in range(0, len(idxs), block):
            hi = min(lo + block, len(idxs))
            pad = block - (hi - lo)
            s = np.concatenate([sizes[lo:hi],
                                np.repeat(sizes[lo:lo + 1], pad, 0)]) \
                if pad else sizes[lo:hi]
            o = np.concatenate([offsets[lo:hi],
                                np.repeat(offsets[lo:lo + 1], pad, 0)]) \
                if pad else offsets[lo:hi]
            warm_key = _warm_key(op, template.name, var_slots, num_pes,
                                 noc_bw, multicast, spatial_reduction,
                                 block)
            sj, oj = jnp.asarray(s), jnp.asarray(o)
            if warm_key not in _WARMED:
                # first call at this shape: jit compile — re-run timed so
                # every group contributes a steady-rate sample
                t0 = time.perf_counter()
                out = np.asarray(f(sj, oj))
                stats.compile_s += time.perf_counter() - t0
                _WARMED.add(warm_key)
            t0 = time.perf_counter()
            out = np.asarray(f(sj, oj))
            stats.eval_s += time.perf_counter() - t0
            stats.n_steady += hi - lo
            feats[idxs[lo:hi]] = out[:hi - lo]
    return feats, stats


def measure_rate(op: LayerOp, space: MapSpace, *, num_pes: int,
                 noc_bw: float, block: int = 4096, seconds: float = 2.0,
                 seed: int = 0, group: GroupKey | None = None,
                 multicast: bool = True, spatial_reduction: bool = True
                 ) -> float:
    """Steady-state batched evaluation rate (mappings/s) on one group —
    the number comparable to the paper's 0.17M designs/s DSE rate."""
    rng = np.random.default_rng(seed)
    key = group if group is not None else space.group_keys()[0]
    template, var_slots = group_template(space, key)
    f = batched_tile_evaluator(
        op, template, var_slots, num_pes=num_pes, noc_bw=noc_bw,
        multicast=multicast, spatial_reduction=spatial_reduction)
    tiles = np.stack([rng.integers(0, ax.n, block) for ax in space.axes], 1)
    pts = [key + tuple(row) for row in tiles]
    sizes, offsets = point_operands(space, pts)
    s, o = jnp.asarray(sizes), jnp.asarray(offsets)
    f(s, o).block_until_ready()  # compile + warm
    n = 0
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < seconds:
        f(s, o).block_until_ready()
        n += block
    return n / (time.perf_counter() - t0)
