"""Universal batched evaluation: the whole mapping space through ONE
XLA executable per (op, level-count).

``repro.mapspace.batched`` groups candidates by (spatial × perm × cluster)
structure and compiles one executable per group — ~5–20 s of XLA time
each, which forced ``search()`` to clamp how many structure groups it
explores.  This module encodes the *entire* gene tuple as vmapped operands
of ``core.vectorized.universal_evaluator`` instead:

  * tile sizes / offsets — as before;
  * the permutation — a rank vector (axis -> position in the loop order);
  * the spatial choice — a one-hot selector;
  * the cluster option — a traced cluster size + a one-hot over the
    space's (inner dim, inner map) candidates;
  * the hardware point (#PEs, NoC bandwidth) — traced per row, so the
    co-DSE's mapping × hardware frontier needs no re-compilation either.

A mapping space therefore costs at most TWO compiles (its 1-level and
2-level families), no matter how many structure groups it spans.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp

from .. import obs
from ..resilience import (CHUNK_WATCHDOG, RetryPolicy, SweepCheckpoint,
                          SweepKilled, array_hash, check_cancel,
                          default_policy, fault_point, is_oom, pack_top,
                          run_attempts, unpack_top)
from ..core.tensor_analysis import LayerOp
from ..core.vectorized import (FEATURES, HWTail, ReduceSpec, UniversalSpec,
                               universal_evaluator,
                               universal_reduced_evaluator)
from .space import (ClusterOption, MapSpace, Point, _resolve_sz,
                    gene_tables)

# Executables warmed at a given block shape this process (same role as
# ``batched._WARMED``).  The matching compile COUNT lives in the obs
# metrics registry (``universal.compiles``): warm_once() is the single
# writer of both, so the process counter, the per-family counters, and
# every run-local ``n_compiles`` (which increments iff warm_once returned
# True) can never drift apart — the whole point of the universal
# evaluator is that this count stays O(1) per (op, level-count), not
# O(groups).
_WARMED: set[tuple] = set()
_WARM_LOCK = threading.Lock()


def compile_count() -> int:
    """Process-wide number of first-call (compiling) universal executions.
    Reads the obs metrics counter that :func:`warm_once` maintains."""
    return int(obs.metrics().value("universal.compiles"))


def is_warm(key: tuple) -> bool:
    """Whether a first-call (compiling) execution was already recorded
    under ``key``."""
    return key in _WARMED


def warm_once(key: tuple, *, family: str | None = None,
              seconds: float = 0.0) -> bool:
    """Record a first-call (compiling) universal execution under an
    arbitrary hashable key; returns True when the key was new.  Every
    universal execution path — batched, gene pipeline, netspace's
    shape-as-operand evaluator — funnels through this so
    :func:`compile_count` (the bench/CI O(1)-compile gate) stays honest.
    Call AFTER the first execution completes (gate on :func:`is_warm`)
    so a failed/interrupted compile is retried and counted, not silently
    treated as warm.

    THE single writer of the compile metrics: bumps ``universal.compiles``
    plus the per-``family`` counter (label e.g. ``conv1:L2``) and
    ``universal.compile_s``.  Callers increment their run-local
    ``n_compiles`` iff this returns True, so run stats and the process
    counter agree by construction (asserted here)."""
    m = obs.metrics()
    with _WARM_LOCK:
        if key in _WARMED:
            return False
        _WARMED.add(key)
        n = m.inc("universal.compiles")
        m.inc("universal.compiles_by_family", family=family or "other")
        if seconds:
            m.inc("universal.compile_s", seconds)
        # parity: the counter counts exactly the warmed keys
        assert int(n) == len(_WARMED), \
            f"compile counter drift: {int(n)} != {len(_WARMED)} warmed keys"
    return True


def mark_warmed(op: LayerOp, spec, multicast: bool, reduction: bool,
                n_rows: int) -> bool:
    """Record a first-call (compiling) universal execution at an ad-hoc
    batch shape — e.g. ``measure_rate``'s timing batches, which bypass
    :func:`evaluate_encoded`.  Returns True when the shape was new."""
    return warm_once(_warm_key(op, spec, multicast, reduction, n_rows),
                     family=family_label(op, spec))


def family_label(op: LayerOp, spec) -> str:
    """Human-readable (op, level-count) family name for metrics/spans:
    ``conv1:L2`` = conv1's 2-level (clustered) executable family."""
    return f"{op.name}:L{2 if getattr(spec, 'cluster', None) else 1}"


def _cluster_candidate(copt: ClusterOption, op: LayerOp
                       ) -> tuple[str, int, int]:
    """Resolved (inner_dim, inner_size, inner_offset) of a cluster option —
    the static inner-map identity the csel one-hot selects over (the
    cluster *size* stays a traced operand)."""
    ext = op.dims[copt.inner_dim]
    return (copt.inner_dim,
            min(_resolve_sz(copt.inner_size, op), ext),
            min(_resolve_sz(copt.inner_offset, op), ext))


def universal_specs(op: LayerOp, space: MapSpace
                    ) -> tuple[UniversalSpec, UniversalSpec | None]:
    """The (1-level, 2-level) executable specs for a space; the 2-level
    spec is ``None`` when the space has no Cluster options."""
    dim_names = tuple(op.dims)
    axis_dims = tuple(ax.dim for ax in space.axes)
    for d in axis_dims:
        if d not in op.dims:
            raise ValueError(f"axis dim {d!r} not an op dim")
    cands: list[tuple[str, int, int]] = []
    for copt in space.cluster_options:
        if copt is None:
            continue
        cand = _cluster_candidate(copt, op)
        if cand not in cands:
            cands.append(cand)
    # MapSpace tiles are divisor-legal by construction: temporal axes never
    # produce an edge phase, so the A+1 single-edge enumeration is exact
    spec1 = UniversalSpec(dim_names=dim_names, axis_dims=axis_dims,
                          pinned=tuple(space.pinned), single_edge=True)
    spec2 = UniversalSpec(dim_names=dim_names, axis_dims=axis_dims,
                          pinned=tuple(space.pinned), cluster=tuple(cands),
                          single_edge=True) if cands else None
    return spec1, spec2


def _candidate_index(space: MapSpace, op: LayerOp,
                     cands: tuple[tuple[str, int, int], ...]
                     ) -> dict[int, tuple[int, int]]:
    """cluster_idx -> (candidate index, cluster size) for non-None options."""
    out: dict[int, tuple[int, int]] = {}
    for ci, copt in enumerate(space.cluster_options):
        if copt is None:
            continue
        out[ci] = (cands.index(_cluster_candidate(copt, op)),
                   int(copt.size))
    return out


def encode_points(op: LayerOp, space: MapSpace, points: Sequence[Point],
                  spec: UniversalSpec, *, num_pes, noc_bw
                  ) -> dict[str, np.ndarray]:
    """Operand arrays for points of ONE level-count family.

    ``num_pes``/``noc_bw`` may be scalars (fixed hardware) or per-point
    arrays (joint mapping × hardware rows)."""
    n, a = len(points), len(space.axes)
    ops = {
        "sizes": np.empty((n, a), np.float32),
        "offsets": np.empty((n, a), np.float32),
        "rank": np.empty((n, a), np.float32),
        "sp": np.zeros((n, a), np.float32),
        "pes": np.broadcast_to(
            np.asarray(num_pes, np.float32), (n,)).copy(),
        "bw": np.broadcast_to(
            np.asarray(noc_bw, np.float32), (n,)).copy(),
    }
    if spec.cluster:
        ops["csize"] = np.empty((n,), np.float32)
        ops["csel"] = np.zeros((n, len(spec.cluster)), np.float32)
        cidx = _candidate_index(space, op, spec.cluster)
    for i, pt in enumerate(points):
        s_i, p_i, c_i = pt[:3]
        tiles = pt[3:]
        for ai, ax in enumerate(space.axes):
            ops["sizes"][i, ai] = ax.sizes[tiles[ai]]
            ops["offsets"][i, ai] = ax.offsets[tiles[ai]]
        for pos, ai in enumerate(space.perms[p_i]):
            ops["rank"][i, ai] = pos
        ops["sp"][i, space.spatial_choices[s_i]] = 1.0
        if spec.cluster:
            if c_i not in cidx:
                raise ValueError(f"point {pt} is not a 2-level mapping")
            k, csize = cidx[c_i]
            ops["csel"][i, k] = 1.0
            ops["csize"][i] = csize
        elif space.cluster_options[c_i] is not None:
            raise ValueError(f"point {pt} is not a 1-level mapping")
    return ops


@dataclasses.dataclass
class UniversalRun:
    """Timing bookkeeping of one universal evaluation pass."""
    n_rows: int = 0
    n_compiles: int = 0
    compile_s: float = 0.0
    eval_s: float = 0.0


def _warm_key(op: LayerOp, spec: UniversalSpec, multicast, reduction,
              block: int) -> tuple:
    return (op.name, tuple(sorted(op.dims.items())), op.op_type, spec,
            bool(multicast), bool(reduction), block)


def evaluate_encoded(op: LayerOp, spec: UniversalSpec,
                     ops: dict[str, np.ndarray], *, block: int = 1024,
                     multicast: bool = True, spatial_reduction: bool = True
                     ) -> tuple[np.ndarray, UniversalRun]:
    """Run one operand batch through the universal executable with fixed
    block padding (so each (spec, block) compiles exactly once per
    process); returns ``(features[n, F], run_stats)``."""
    f = universal_evaluator(op, spec, multicast=multicast,
                            spatial_reduction=spatial_reduction)
    n = len(ops["pes"])
    feats = np.empty((n, len(FEATURES)), np.float32)
    run = UniversalRun(n_rows=n)
    wk = _warm_key(op, spec, multicast, spatial_reduction, block)
    for lo in range(0, n, block):
        hi = min(lo + block, n)
        pad = block - (hi - lo)
        batch = {}
        for k, v in ops.items():
            chunk = v[lo:hi]
            if pad:
                chunk = np.concatenate(
                    [chunk, np.repeat(v[lo:lo + 1], pad, 0)])
            batch[k] = jnp.asarray(chunk)
        fam = family_label(op, spec)
        if not is_warm(wk):
            # first call at this shape: jit compile — re-run timed so every
            # batch contributes a steady-rate sample
            with obs.span("compile", family=fam, rows=block):
                t0 = time.perf_counter()
                np.asarray(f(batch))
                dt = time.perf_counter() - t0
            if warm_once(wk, family=fam, seconds=dt):
                run.compile_s += dt
                run.n_compiles += 1
        else:
            obs.metrics().inc("universal.warm_hits", family=fam)
        with obs.span("device-pass", family=fam, rows=hi - lo):
            t0 = time.perf_counter()
            out = np.asarray(f(batch))
            run.eval_s += time.perf_counter() - t0
        feats[lo:hi] = out[:hi - lo]
    return feats, run


# ----------------------------------------------------------------------
# Gene pipeline: vectorized encode + async sharded device-resident DSE
# ----------------------------------------------------------------------

def encode_genes_base(op: LayerOp, space: MapSpace, genes: np.ndarray, *,
                      num_pes, noc_bw) -> dict[str, np.ndarray]:
    """The cluster-agnostic part of :func:`encode_genes` — tile sizes/
    offsets, permutation ranks, spatial one-hot and the hardware point —
    shared with ``repro.netspace``'s shape-as-operand encoder (which adds
    its own ``ext``/cluster columns)."""
    tb = gene_tables(op, space)
    genes = np.asarray(genes, np.int64)
    n, a = genes.shape[0], len(space.axes)
    tiles = genes[:, 3:]
    ar = np.arange(a)[None, :]
    sp = np.zeros((n, a), np.float32)
    sp[np.arange(n), tb.spatial_axis[genes[:, 0]]] = 1.0
    return {
        "sizes": tb.size_tab[ar, tiles],
        "offsets": tb.off_tab[ar, tiles],
        "rank": tb.perm_rank[genes[:, 1]],
        "sp": sp,
        "pes": np.broadcast_to(
            np.asarray(num_pes, np.float32), (n,)).copy(),
        "bw": np.broadcast_to(
            np.asarray(noc_bw, np.float32), (n,)).copy(),
    }


def encode_genes(op: LayerOp, space: MapSpace, genes: np.ndarray,
                 spec: UniversalSpec, *, num_pes, noc_bw
                 ) -> dict[str, np.ndarray]:
    """Vectorized :func:`encode_points` over an (n, G) gene matrix: all
    operand arrays are built by numpy gathers over the space's lookup
    tables (``space.gene_tables``) and one-hot scatters — no Python
    per-point loop.  Produces byte-identical operands to the legacy
    per-point encoder (the parity-oracle path)."""
    tb = gene_tables(op, space)
    genes = np.asarray(genes, np.int64)
    n = genes.shape[0]
    ops = encode_genes_base(op, space, genes, num_pes=num_pes,
                            noc_bw=noc_bw)
    is_none = tb.cluster_is_none[genes[:, 2]]
    if spec.cluster:
        if is_none.any():
            raise ValueError("1-level rows passed to a 2-level spec")
        cidx = _candidate_index(space, op, spec.cluster)
        cand_of = np.full(len(space.cluster_options), -1, np.int64)
        for ci, (kk, _) in cidx.items():
            cand_of[ci] = kk
        csel = np.zeros((n, len(spec.cluster)), np.float32)
        csel[np.arange(n), cand_of[genes[:, 2]]] = 1.0
        ops["csel"] = csel
        ops["csize"] = tb.csize_tab[genes[:, 2]]
    elif not is_none.all():
        raise ValueError("2-level rows passed to a 1-level spec")
    return ops


@dataclasses.dataclass
class GeneRun:
    """Timing/size bookkeeping of one gene-pipeline evaluation.

    ``encode_s`` is host time building + transferring operand chunks;
    ``eval_s`` is time the host spent *blocked* on device results (a lower
    bound on device time — encode of chunk i+1 overlaps evaluation of
    chunk i); ``e2e_s`` is the full wall time of the pass."""
    n_rows: int = 0
    n_valid: int = 0
    n_steady: int = 0        # rows dispatched in steady (non-compile) chunks
    n_compiles: int = 0
    compile_s: float = 0.0
    eval_s: float = 0.0
    encode_s: float = 0.0
    e2e_s: float = 0.0
    n_devices: int = 1

    def merge(self, other: "GeneRun") -> None:
        self.n_rows += other.n_rows
        self.n_valid += other.n_valid
        self.n_steady += other.n_steady
        self.n_compiles += other.n_compiles
        self.compile_s += other.compile_s
        self.eval_s += other.eval_s
        self.encode_s += other.encode_s
        self.e2e_s += other.e2e_s
        self.n_devices = max(self.n_devices, other.n_devices)


@dataclasses.dataclass
class GeneEval:
    """Result of one device-resident evaluation pass over a gene matrix.

    ``top`` rows are global indices into the input gene matrix; ``values``
    are canonical-minimize objective values (negate for maximize
    objectives).  ``pareto`` is the exact (energy min, throughput max)
    frontier over all evaluated rows, host-refined from the per-chunk
    device candidate masks."""
    top: list[dict]                    # [{row, value, feats}]
    pareto: list[dict]                 # [{row, energy_pj, throughput}]
    run: GeneRun
    vals: np.ndarray | None = None     # (n,) objective column (optional)


def _pad_rows(v: np.ndarray, pad: int) -> np.ndarray:
    if not pad:
        return v
    return np.concatenate([v, np.repeat(v[-1:], pad, axis=0)])


def pareto_front(entries: Sequence[dict], x: str = "energy_pj",
                 y: str = "throughput") -> list[dict]:
    """Exact (min ``x``, max ``y``) frontier over candidate dicts — THE
    host-side refinement shared by the gene pipeline and the co-DSE
    (sorted() is stable, so ties keep the callers' row order)."""
    order = sorted(range(len(entries)),
                   key=lambda i: (entries[i][x], -entries[i][y]))
    front, best = [], -np.inf
    for i in order:
        if entries[i][y] > best and np.isfinite(entries[i][x]):
            best = entries[i][y]
            front.append(entries[i])
    return front


def evaluate_genes(op: LayerOp, space: MapSpace, genes: np.ndarray, *,
                   objective: str = "edp", maximize: bool = False,
                   k: int = 8, num_pes, noc_bw, block: int = 1024,
                   n_devices: int | None = None, depth: int = 2,
                   multicast: bool = True, spatial_reduction: bool = True,
                   return_vals: bool = True, pareto: bool = True,
                   hw_tail: HWTail | None = None,
                   ckpt: SweepCheckpoint | None = None,
                   retry: RetryPolicy | None = None,
                   _splits_left: int | None = None) -> GeneEval:
    """Device-resident evaluation of a gene matrix: vectorized encode,
    async double-buffered dispatch (chunk i+1 encodes on the host while
    chunk i evaluates), chunks striped over ``n_devices`` local devices
    (default: all), and the objective/top-k/Pareto reduction fused into
    the executable — each chunk returns k winner rows plus a small
    frontier slice instead of the (n, F) feature matrix.

    ``objective`` is a FEATURES column name; ``num_pes``/``noc_bw`` may be
    scalars or per-row arrays (joint mapping x hardware rows); ``hw_tail``
    folds run_dse-style area/power/leakage accounting into the jit.
    Results are deterministic and identical for any device count.

    Resilience: every chunk runs under ``retry`` (default: the
    installed ``resilience.default_policy()``) — a failed device pass
    re-encodes and
    re-dispatches with backoff; device OOM recursively re-evaluates just
    the failed chunk at half the block size on one device
    (``resilience.chunk_splits``); budget exhaustion surfaces a
    ``DeviceError``.  With ``ckpt`` (a ``resilience.SweepCheckpoint``)
    the running accumulators are persisted every few chunks, and a
    killed sweep resumes from the last saved chunk boundary with
    bit-identical final results: merges are order-insensitive (top-k
    sorts on (value, row); the Pareto refinement argsorts candidates by
    row) and the chunk layout is pinned by the checkpoint's meta guard
    (row count, block, device count, content hash)."""
    t_start = time.perf_counter()
    genes = np.asarray(genes, np.int64)
    n = genes.shape[0]
    nd = n_devices if n_devices is not None else jax.local_device_count()
    nd = max(1, min(nd, jax.local_device_count()))
    retry = retry or default_policy()
    splits_left = retry.max_splits if _splits_left is None else _splits_left
    spec1, spec2 = universal_specs(op, space)
    pes = np.broadcast_to(np.asarray(num_pes, np.float32), (n,))
    bw = np.broadcast_to(np.asarray(noc_bw, np.float32), (n,))
    is2 = ~gene_tables(op, space).cluster_is_none[genes[:, 2]]

    run = GeneRun(n_rows=n, n_devices=nd)
    vals = np.empty(n, np.float64) if return_vals else None
    top_entries: list[tuple[float, int, np.ndarray]] = []
    cand_rows: list[np.ndarray] = []
    cand_e: list[np.ndarray] = []
    cand_t: list[np.ndarray] = []

    def collect(sub: np.ndarray, m: int, out: dict) -> None:
        met = obs.metrics()
        # the blocked wait for (and host copy of) this chunk's reduced
        # device results — the host-visible tail of the device pass
        with obs.span("device-pass", op=op.name, rows=m, devices=nd):
            t0 = time.perf_counter()
            host = {kk: np.asarray(v) for kk, v in out.items()}
            dt = time.perf_counter() - t0
        run.eval_s += dt
        met.observe("gene.collect_wait_s", dt)
        met.inc("gene.merge_bytes", sum(v.nbytes for v in host.values()))
        chunk_rows = nd * block
        with obs.span("topk-merge", op=op.name, rows=m):
            if return_vals:
                vals[sub] = host["vals"].reshape(chunk_rows)[:m]
            tv = host["top_vals"].reshape(-1)
            ti = host["top_idx"].reshape(-1).astype(np.int64)
            tf = host["top_feats"].reshape(-1, len(FEATURES))
            if nd > 1:  # local shard index -> chunk row
                kk = host["top_vals"].shape[-1]
                ti = ti + np.repeat(np.arange(nd) * block, kk)
            # padding rows can never reach the top (live=0 forces obj=inf
            # AND idx >= m); real rows with an inf objective are kept,
            # mirroring the legacy host reduction which sorts them last
            # rather than dropping them
            keep = ti < m
            for v, i, row in zip(tv[keep], ti[keep], tf[keep]):
                top_entries.append((float(v), int(sub[i]), row))
            run.n_valid += int(np.sum(host["n_valid"]))
            if pareto:
                mask = host["pareto_mask"].reshape(chunk_rows)[:m]
                w = np.where(mask)[0]
                cand_rows.append(sub[w])
                cand_e.append(
                    host["pareto_energy"].reshape(chunk_rows)[:m][w])
                cand_t.append(
                    host["pareto_thr"].reshape(chunk_rows)[:m][w])

    def safe_collect(sub: np.ndarray, m: int, out: dict) -> None:
        # transactional merge: roll back partial accumulator appends on
        # failure so a retried collect never duplicates top/Pareto rows
        marks = (len(top_entries), len(cand_rows), run.n_valid)
        try:
            collect(sub, m, out)
        except Exception:
            del top_entries[marks[0]:]
            del cand_rows[marks[1]:]
            del cand_e[marks[1]:]
            del cand_t[marks[1]:]
            run.n_valid = marks[2]
            raise

    met = obs.metrics()
    met.inc("gene.rows_evaluated", n)
    n_compiles_at_entry = run.n_compiles
    c0 = compile_count()

    # -- resilience state: resume cursor + periodic checkpoint ----------
    start_cursor = 0           # chunks already merged by a prior run
    chunks_done = 0            # chunks merged so far, in dispatch order
    gidx = 0                   # global dispatch index across families
    ckpt_meta: dict | None = None
    if ckpt is not None:
        ckpt_meta = {"key": ckpt.key, "n": int(n), "block": int(block),
                     "nd": int(nd), "objective": objective,
                     "maximize": bool(maximize), "k": int(k),
                     "pareto": bool(pareto),
                     "return_vals": bool(return_vals),
                     "content": array_hash(genes, pes, bw)}
        st = ckpt.load(ckpt_meta)
        if st is not None:
            start_cursor = chunks_done = int(st["cursor"])
            run.n_valid = int(st["n_valid"])
            top_entries.extend(unpack_top(st))
            if return_vals and "vals" in st:
                vals[:] = st["vals"]
            if pareto and st["cand_rows"].size:
                cand_rows.append(st["cand_rows"].astype(np.int64))
                cand_e.append(st["cand_e"])
                cand_t.append(st["cand_t"])

    def ckpt_state() -> dict:
        state = {"cursor": chunks_done, "n_valid": run.n_valid,
                 **pack_top(top_entries)}
        if return_vals:
            state["vals"] = vals
        if pareto:
            state["cand_rows"] = (np.concatenate(cand_rows)
                                  if cand_rows else np.zeros(0, np.int64))
            state["cand_e"] = (np.concatenate(cand_e)
                              if cand_e else np.zeros(0, np.float32))
            state["cand_t"] = (np.concatenate(cand_t)
                              if cand_t else np.zeros(0, np.float32))
        return state

    def split_eval(sub: np.ndarray) -> None:
        # OOM recovery: the same rows at half the block on one device —
        # an independent exact evaluation whose merge is bit-transparent
        # (a row dominated within any sub-chunk can never reach the
        # global frontier, and the top-k merge sorts on (value, row))
        rec = evaluate_genes(
            op, space, genes[sub], objective=objective, maximize=maximize,
            k=k, num_pes=pes[sub], noc_bw=bw[sub],
            block=max(retry.min_rows, block // 2), n_devices=1,
            depth=depth, multicast=multicast,
            spatial_reduction=spatial_reduction, return_vals=return_vals,
            pareto=pareto, hw_tail=hw_tail, retry=retry,
            _splits_left=splits_left - 1)
        if return_vals:
            vals[sub] = rec.vals
        for t in rec.top:
            top_entries.append((float(t["value"]), int(sub[t["row"]]),
                                t["feats"]))
        if pareto and rec.pareto:
            rws = np.array([p["row"] for p in rec.pareto], np.int64)
            cand_rows.append(sub[rws])
            cand_e.append(np.array([p["energy_pj"] for p in rec.pareto],
                                   np.float64))
            cand_t.append(np.array([p["throughput"] for p in rec.pareto],
                                   np.float64))
        run.n_valid += rec.run.n_valid
        run.n_steady += rec.run.n_steady
        run.n_compiles += rec.run.n_compiles
        run.compile_s += rec.run.compile_s
        run.eval_s += rec.run.eval_s
        run.encode_s += rec.run.encode_s

    for spec, fam in ((spec1, np.where(~is2)[0]),
                      (spec2, np.where(is2)[0])):
        if fam.size == 0:
            continue
        assert spec is not None
        fam_label = family_label(op, spec)
        chunk_rows = nd * block
        reduce = ReduceSpec(objective=objective, maximize=maximize,
                            k=min(k, chunk_rows), return_vals=return_vals,
                            pareto=pareto, hw=hw_tail)
        f = universal_reduced_evaluator(
            op, spec, reduce, multicast=multicast,
            spatial_reduction=spatial_reduction, n_devices=nd)
        wk = (_warm_key(op, spec, multicast, spatial_reduction,
                        chunk_rows), reduce, nd)
        pending: collections.deque = collections.deque()

        def make_chunk(sub, m, in_flight):
            with obs.span("encode", family=fam_label, rows=m):
                t0 = time.perf_counter()
                batch = encode_genes(op, space, genes[sub], spec,
                                     num_pes=pes[sub], noc_bw=bw[sub])
                pad = chunk_rows - m
                live = np.zeros(chunk_rows, np.float32)
                live[:m] = 1.0
                batch = {kk: _pad_rows(v, pad) for kk, v in batch.items()}
                batch["live"] = live
                if nd > 1:
                    batch = {kk: v.reshape((nd, block) + v.shape[1:])
                             for kk, v in batch.items()}
                jbatch = {kk: jnp.asarray(v) for kk, v in batch.items()}
                t_enc = time.perf_counter() - t0
                run.encode_s += t_enc
            if in_flight:
                # double-buffer overlap, measured not guessed: host
                # encode time spent while >= 1 chunk was in flight
                met.inc("gene.overlap_encode_s", t_enc)
            met.observe("gene.chunk_occupancy", m / chunk_rows)
            return jbatch

        def dispatch(jbatch, m):
            check_cancel("chunk")
            fault_point("chunk")
            if not is_warm(wk):
                with obs.span("compile", family=fam_label,
                              rows=chunk_rows, devices=nd):
                    t0 = time.perf_counter()
                    out = f(jbatch)
                    jax.block_until_ready(out)
                    dt = time.perf_counter() - t0
                if warm_once(wk, family=fam_label, seconds=dt):
                    run.compile_s += dt
                    run.n_compiles += 1
            else:
                met.inc("universal.warm_hits", family=fam_label)
                with obs.span("dispatch", family=fam_label, rows=m,
                              devices=nd):
                    t0 = time.perf_counter()
                    out = f(jbatch)    # async dispatch
                    met.observe("gene.dispatch_s",
                                time.perf_counter() - t0)
                run.n_steady += m
            return out

        def recover(sub, m, exc):
            if isinstance(exc, SweepKilled):
                raise exc            # simulated process death: no retry
            if is_oom(exc) and splits_left > 0 and block > retry.min_rows:
                met.inc("resilience.chunk_splits")
                obs.instant("chunk-split", family=fam_label, rows=int(m),
                            block=block,
                            to=max(retry.min_rows, block // 2))
                split_eval(sub)
                return

            def once():
                safe_collect(sub, m, dispatch(make_chunk(sub, m, False),
                                              m))
            run_attempts(once, policy=retry,
                         label=f"{fam_label} chunk", first_exc=exc)

        def finish(sub, m, out, t_disp):
            nonlocal chunks_done
            try:
                safe_collect(sub, m, out)
            except Exception as exc:  # noqa: BLE001 — recover classifies
                recover(sub, m, exc)
            wall = time.perf_counter() - t_disp
            CHUNK_WATCHDOG.observe(wall, family=fam_label, rows=int(m))
            retry.check_deadline(wall, family=fam_label, rows=int(m))
            chunks_done += 1
            if ckpt is not None:
                ckpt.maybe_save(ckpt_state, ckpt_meta,
                                chunks_done=chunks_done)

        for lo in range(0, fam.size, chunk_rows):
            if gidx < start_cursor:
                gidx += 1        # merged by the resumed checkpoint
                continue
            gidx += 1
            sub = fam[lo:lo + chunk_rows]
            m = sub.size
            try:
                out = dispatch(make_chunk(sub, m, bool(pending)), m)
            except Exception as exc:  # noqa: BLE001 — recover classifies
                # drain in dispatch order first so the chunk cursor stays
                # contiguous, then recover this chunk synchronously
                while pending:
                    finish(*pending.popleft())
                recover(sub, m, exc)
                chunks_done += 1
                if ckpt is not None:
                    ckpt.maybe_save(ckpt_state, ckpt_meta,
                                    chunks_done=chunks_done)
                continue
            pending.append((sub, m, out, time.perf_counter()))
            while len(pending) > depth:
                finish(*pending.popleft())
        while pending:
            finish(*pending.popleft())
    # run-local vs process compile accounting cannot drift: both increment
    # on the same warm_once() event (recursive split merges move both)
    assert compile_count() - c0 == run.n_compiles - n_compiles_at_entry
    if ckpt is not None:
        ckpt.clear()               # completed: the checkpoint is spent

    top_entries.sort(key=lambda e: (e[0], e[1]))
    top = [{"row": r, "value": v, "feats": fr}
           for v, r, fr in top_entries[:k]]
    front: list[dict] = []
    if pareto and cand_rows:
        rows = np.concatenate(cand_rows)
        es = np.concatenate(cand_e)
        ts = np.concatenate(cand_t)
        by_row = np.argsort(rows, kind="stable")
        front = pareto_front(
            [{"row": int(rows[i]), "energy_pj": float(es[i]),
              "throughput": float(ts[i])} for i in by_row])
    run.e2e_s = time.perf_counter() - t_start
    # blocked-wait time understates device time under overlap; wall minus
    # host work is the tighter lower bound of the two
    run.eval_s = max(run.eval_s,
                     run.e2e_s - run.encode_s - run.compile_s)
    return GeneEval(top=top, pareto=front, run=run, vals=vals)


def evaluate_points_universal(op: LayerOp, space: MapSpace,
                              points: Sequence[Point], *, num_pes,
                              noc_bw, block: int = 1024,
                              multicast: bool = True,
                              spatial_reduction: bool = True
                              ) -> tuple[np.ndarray, UniversalRun]:
    """Evaluate arbitrary mapping points — any mix of structure groups —
    with at most TWO compiles (1-level + 2-level families).

    ``num_pes``/``noc_bw`` may be per-point arrays: the hardware point is
    an operand of the same executable (the co-DSE's joint frontier)."""
    spec1, spec2 = universal_specs(op, space)
    pes = np.broadcast_to(np.asarray(num_pes, np.float32),
                          (len(points),))
    bw = np.broadcast_to(np.asarray(noc_bw, np.float32), (len(points),))
    lvl1_idx = [i for i, pt in enumerate(points)
                if space.cluster_options[pt[2]] is None]
    lvl2_idx = [i for i, pt in enumerate(points)
                if space.cluster_options[pt[2]] is not None]
    feats = np.empty((len(points), len(FEATURES)), np.float32)
    run = UniversalRun(n_rows=len(points))
    for spec, idxs in ((spec1, lvl1_idx), (spec2, lvl2_idx)):
        if not idxs:
            continue
        assert spec is not None
        ops = encode_points(op, space, [points[i] for i in idxs], spec,
                            num_pes=pes[idxs], noc_bw=bw[idxs])
        sub, r = evaluate_encoded(op, spec, ops, block=block,
                                  multicast=multicast,
                                  spatial_reduction=spatial_reduction)
        feats[idxs] = sub
        run.n_compiles += r.n_compiles
        run.compile_s += r.compile_s
        run.eval_s += r.eval_s
    return feats, run
