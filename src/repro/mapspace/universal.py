"""Universal batched evaluation: the whole mapping space through ONE
XLA executable per (op, level-count).

``repro.mapspace.batched`` groups candidates by (spatial × perm × cluster)
structure and compiles one executable per group — ~5–20 s of XLA time
each, which forced ``search()`` to clamp how many structure groups it
explores.  This module encodes the *entire* gene tuple as vmapped operands
of ``core.vectorized.universal_evaluator`` instead:

  * tile sizes / offsets — as before;
  * the permutation — a rank vector (axis -> position in the loop order);
  * the spatial choice — a one-hot selector;
  * the cluster option — a traced cluster size + a one-hot over the
    space's (inner dim, inner map) candidates;
  * the hardware point (#PEs, NoC bandwidth) — traced per row, so the
    co-DSE's mapping × hardware frontier needs no re-compilation either.

A mapping space therefore costs at most TWO compiles (its 1-level and
2-level families), no matter how many structure groups it spans.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import numpy as np
import jax.numpy as jnp

from ..core.tensor_analysis import LayerOp
from ..core.vectorized import FEATURES, UniversalSpec, universal_evaluator
from .space import ClusterOption, MapSpace, Point, _resolve_sz

# Executables warmed at a given block shape this process (same role as
# ``batched._WARMED``), plus a monotone compile counter for regression
# tests and benchmarks: the whole point of the universal evaluator is that
# this counter stays O(1) per (op, level-count), not O(groups).
_WARMED: set[tuple] = set()
_COMPILE_COUNT = 0


def compile_count() -> int:
    """Process-wide number of first-call (compiling) universal executions."""
    return _COMPILE_COUNT


def mark_warmed(op: LayerOp, spec, multicast: bool, reduction: bool,
                n_rows: int) -> bool:
    """Record a first-call (compiling) universal execution at an ad-hoc
    batch shape — e.g. ``measure_rate``'s timing batches, which bypass
    :func:`evaluate_encoded`.  Returns True when the shape was new.  Keeps
    :func:`compile_count` honest for every universal execution path (the
    bench/CI O(1)-compile gate counts through it)."""
    global _COMPILE_COUNT
    key = _warm_key(op, spec, multicast, reduction, n_rows)
    if key in _WARMED:
        return False
    _WARMED.add(key)
    _COMPILE_COUNT += 1
    return True


def _cluster_candidate(copt: ClusterOption, op: LayerOp
                       ) -> tuple[str, int, int]:
    """Resolved (inner_dim, inner_size, inner_offset) of a cluster option —
    the static inner-map identity the csel one-hot selects over (the
    cluster *size* stays a traced operand)."""
    ext = op.dims[copt.inner_dim]
    return (copt.inner_dim,
            min(_resolve_sz(copt.inner_size, op), ext),
            min(_resolve_sz(copt.inner_offset, op), ext))


def universal_specs(op: LayerOp, space: MapSpace
                    ) -> tuple[UniversalSpec, UniversalSpec | None]:
    """The (1-level, 2-level) executable specs for a space; the 2-level
    spec is ``None`` when the space has no Cluster options."""
    dim_names = tuple(op.dims)
    axis_dims = tuple(ax.dim for ax in space.axes)
    for d in axis_dims:
        if d not in op.dims:
            raise ValueError(f"axis dim {d!r} not an op dim")
    cands: list[tuple[str, int, int]] = []
    for copt in space.cluster_options:
        if copt is None:
            continue
        cand = _cluster_candidate(copt, op)
        if cand not in cands:
            cands.append(cand)
    # MapSpace tiles are divisor-legal by construction: temporal axes never
    # produce an edge phase, so the A+1 single-edge enumeration is exact
    spec1 = UniversalSpec(dim_names=dim_names, axis_dims=axis_dims,
                          pinned=tuple(space.pinned), single_edge=True)
    spec2 = UniversalSpec(dim_names=dim_names, axis_dims=axis_dims,
                          pinned=tuple(space.pinned), cluster=tuple(cands),
                          single_edge=True) if cands else None
    return spec1, spec2


def _candidate_index(space: MapSpace, op: LayerOp,
                     cands: tuple[tuple[str, int, int], ...]
                     ) -> dict[int, tuple[int, int]]:
    """cluster_idx -> (candidate index, cluster size) for non-None options."""
    out: dict[int, tuple[int, int]] = {}
    for ci, copt in enumerate(space.cluster_options):
        if copt is None:
            continue
        out[ci] = (cands.index(_cluster_candidate(copt, op)),
                   int(copt.size))
    return out


def encode_points(op: LayerOp, space: MapSpace, points: Sequence[Point],
                  spec: UniversalSpec, *, num_pes, noc_bw
                  ) -> dict[str, np.ndarray]:
    """Operand arrays for points of ONE level-count family.

    ``num_pes``/``noc_bw`` may be scalars (fixed hardware) or per-point
    arrays (joint mapping × hardware rows)."""
    n, a = len(points), len(space.axes)
    ops = {
        "sizes": np.empty((n, a), np.float32),
        "offsets": np.empty((n, a), np.float32),
        "rank": np.empty((n, a), np.float32),
        "sp": np.zeros((n, a), np.float32),
        "pes": np.broadcast_to(
            np.asarray(num_pes, np.float32), (n,)).copy(),
        "bw": np.broadcast_to(
            np.asarray(noc_bw, np.float32), (n,)).copy(),
    }
    if spec.cluster:
        ops["csize"] = np.empty((n,), np.float32)
        ops["csel"] = np.zeros((n, len(spec.cluster)), np.float32)
        cidx = _candidate_index(space, op, spec.cluster)
    for i, pt in enumerate(points):
        s_i, p_i, c_i = pt[:3]
        tiles = pt[3:]
        for ai, ax in enumerate(space.axes):
            ops["sizes"][i, ai] = ax.sizes[tiles[ai]]
            ops["offsets"][i, ai] = ax.offsets[tiles[ai]]
        for pos, ai in enumerate(space.perms[p_i]):
            ops["rank"][i, ai] = pos
        ops["sp"][i, space.spatial_choices[s_i]] = 1.0
        if spec.cluster:
            if c_i not in cidx:
                raise ValueError(f"point {pt} is not a 2-level mapping")
            k, csize = cidx[c_i]
            ops["csel"][i, k] = 1.0
            ops["csize"][i] = csize
        elif space.cluster_options[c_i] is not None:
            raise ValueError(f"point {pt} is not a 1-level mapping")
    return ops


@dataclasses.dataclass
class UniversalRun:
    """Timing bookkeeping of one universal evaluation pass."""
    n_rows: int = 0
    n_compiles: int = 0
    compile_s: float = 0.0
    eval_s: float = 0.0


def _warm_key(op: LayerOp, spec: UniversalSpec, multicast, reduction,
              block: int) -> tuple:
    return (op.name, tuple(sorted(op.dims.items())), op.op_type, spec,
            bool(multicast), bool(reduction), block)


def evaluate_encoded(op: LayerOp, spec: UniversalSpec,
                     ops: dict[str, np.ndarray], *, block: int = 1024,
                     multicast: bool = True, spatial_reduction: bool = True
                     ) -> tuple[np.ndarray, UniversalRun]:
    """Run one operand batch through the universal executable with fixed
    block padding (so each (spec, block) compiles exactly once per
    process); returns ``(features[n, F], run_stats)``."""
    global _COMPILE_COUNT
    f = universal_evaluator(op, spec, multicast=multicast,
                            spatial_reduction=spatial_reduction)
    n = len(ops["pes"])
    feats = np.empty((n, len(FEATURES)), np.float32)
    run = UniversalRun(n_rows=n)
    wk = _warm_key(op, spec, multicast, spatial_reduction, block)
    for lo in range(0, n, block):
        hi = min(lo + block, n)
        pad = block - (hi - lo)
        batch = {}
        for k, v in ops.items():
            chunk = v[lo:hi]
            if pad:
                chunk = np.concatenate(
                    [chunk, np.repeat(v[lo:lo + 1], pad, 0)])
            batch[k] = jnp.asarray(chunk)
        if wk not in _WARMED:
            # first call at this shape: jit compile — re-run timed so every
            # batch contributes a steady-rate sample
            t0 = time.perf_counter()
            np.asarray(f(batch))
            run.compile_s += time.perf_counter() - t0
            run.n_compiles += 1
            _COMPILE_COUNT += 1
            _WARMED.add(wk)
        t0 = time.perf_counter()
        out = np.asarray(f(batch))
        run.eval_s += time.perf_counter() - t0
        feats[lo:hi] = out[:hi - lo]
    return feats, run


def evaluate_points_universal(op: LayerOp, space: MapSpace,
                              points: Sequence[Point], *, num_pes,
                              noc_bw, block: int = 1024,
                              multicast: bool = True,
                              spatial_reduction: bool = True
                              ) -> tuple[np.ndarray, UniversalRun]:
    """Evaluate arbitrary mapping points — any mix of structure groups —
    with at most TWO compiles (1-level + 2-level families).

    ``num_pes``/``noc_bw`` may be per-point arrays: the hardware point is
    an operand of the same executable (the co-DSE's joint frontier)."""
    spec1, spec2 = universal_specs(op, space)
    pes = np.broadcast_to(np.asarray(num_pes, np.float32),
                          (len(points),))
    bw = np.broadcast_to(np.asarray(noc_bw, np.float32), (len(points),))
    lvl1_idx = [i for i, pt in enumerate(points)
                if space.cluster_options[pt[2]] is None]
    lvl2_idx = [i for i, pt in enumerate(points)
                if space.cluster_options[pt[2]] is not None]
    feats = np.empty((len(points), len(FEATURES)), np.float32)
    run = UniversalRun(n_rows=len(points))
    for spec, idxs in ((spec1, lvl1_idx), (spec2, lvl2_idx)):
        if not idxs:
            continue
        assert spec is not None
        ops = encode_points(op, space, [points[i] for i in idxs], spec,
                            num_pes=pes[idxs], noc_bw=bw[idxs])
        sub, r = evaluate_encoded(op, spec, ops, block=block,
                                  multicast=multicast,
                                  spatial_reduction=spatial_reduction)
        feats[idxs] = sub
        run.n_compiles += r.n_compiles
        run.compile_s += r.compile_s
        run.eval_s += r.eval_s
    return feats, run
