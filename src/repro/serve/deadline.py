"""Per-request deadline budgets for the serving tier.

Every admitted request carries ONE wall-clock budget, fixed at
admission: ``search.deadline_s`` from the query itself, else the
server's default.  The budget is enforced in three places, outermost
wins:

  * the engine — :func:`repro.resilience.cancel_scope` around the flush
    makes the chunk loops stop at the next chunk boundary
    (``BudgetExceeded`` → the whole flush answers with timeout reports);
  * the flush — requests already expired when their batch is picked up
    are answered ``where="queued"`` without any engine work;
  * the HTTP handler — an ``asyncio.wait_for`` backstop (budget plus a
    small grace for the in-flight chunk) guarantees the response socket
    NEVER hangs, whatever state the engine is in.

An expired request always gets a terminal ``kind="timeout"`` report
(:meth:`repro.api.Report.timeout`), never a dropped connection.
"""
from __future__ import annotations

import dataclasses
import time

from ..api import Query, Report


@dataclasses.dataclass(frozen=True)
class Deadline:
    """One request's absolute budget: ``t`` is the monotonic expiry
    (None = unbounded), ``budget_s`` the original relative budget."""
    t: float | None
    budget_s: float | None

    @staticmethod
    def stamp(query: Query, default_s: float | None) -> "Deadline":
        budget = query.search.deadline_s
        if budget is None:
            budget = default_s
        t = None if budget is None else time.monotonic() + float(budget)
        return Deadline(t=t, budget_s=budget)

    def remaining(self) -> float | None:
        """Seconds left (may be negative); None when unbounded."""
        return None if self.t is None else self.t - time.monotonic()

    def expired(self) -> bool:
        return self.t is not None and time.monotonic() >= self.t

    def timeout_report(self, query: Query, *, where: str) -> Report:
        waited = 0.0 if self.t is None or self.budget_s is None else \
            time.monotonic() - (self.t - self.budget_s)
        return Report.timeout(query, deadline_s=self.budget_s,
                              waited_s=max(waited, 0.0), where=where)


def batch_deadline_t(deadlines: list[Deadline]) -> float | None:
    """The cancel-scope bound for one coalesced flush: the most patient
    member's expiry (an unbounded member keeps the flush unbounded —
    its work must be allowed to finish)."""
    ts = [d.t for d in deadlines]
    if not ts or any(t is None for t in ts):
        return None
    return max(ts)
