"""Graceful draining shutdown and crash recovery for the serving tier.

The drain contract (SIGTERM):

  1. stop admitting (``/readyz`` flips to 503; new queries answer 429);
  2. PERSIST every admitted-but-unanswered request to
     ``serve-pending.json`` (atomic tmp + ``os.replace``, same commit
     protocol as ``sweepckpt``) — in wire format, so the file
     round-trips through :meth:`repro.api.Query.from_json`;
  3. flush the in-flight families — under the session's checkpoint
     directory, so a kill mid-drain leaves resumable
     ``sweep-batch-*`` checkpoints behind (``kill@serve-drain`` fires
     between steps 2 and 3: the deterministic chaos drill for exactly
     that death);
  4. on a CLEAN drain, delete the pending file and exit.

Recovery (server start): a surviving ``serve-pending.json`` means the
previous process died owing answers.  The queries are re-executed
through the same :func:`~repro.serve.coalescer.execute_batch` path —
identical fingerprints find the identical sweep checkpoints, so the
re-run resumes bit-identically — and their deterministic result slices
are written to ``serve-recovered.json`` (the artifact CI compares
against the offline oracle).  The original clients are gone; the warm
executables, result caches, and recovered artifact are what survives.
"""
from __future__ import annotations

import json
import logging
import os
from typing import Any

from .. import obs
from ..api import Query, Session

LOG = logging.getLogger("repro.serve")

PENDING_NAME = "serve-pending.json"
RECOVERED_NAME = "serve-recovered.json"
TRACE_NAME = "serve-trace.json"
METRICS_NAME = "serve-metrics.json"


def pending_path(ckpt_dir: str) -> str:
    return os.path.join(ckpt_dir, PENDING_NAME)


def recovered_path(ckpt_dir: str) -> str:
    return os.path.join(ckpt_dir, RECOVERED_NAME)


def _atomic_write_json(path: str, payload: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp-{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, path)


def save_observability(out_dir: str,
                       metrics_snapshot: dict) -> dict[str, str | None]:
    """Flush the observability state as part of the drain: the active
    tracer's events (``serve-trace.json`` — previously lost on SIGTERM,
    the tracer only ever saved on CLI exit) and a final metrics
    snapshot (``serve-metrics.json``).  Returns the written paths
    (trace is None when tracing is off)."""
    os.makedirs(out_dir, exist_ok=True)
    trace_path = obs.save_trace(os.path.join(out_dir, TRACE_NAME))
    metrics_path = os.path.join(out_dir, METRICS_NAME)
    _atomic_write_json(metrics_path, metrics_snapshot)
    obs.instant("serve-obs-saved", trace=bool(trace_path))
    return {"trace": trace_path, "metrics": metrics_path}


def persist_pending(ckpt_dir: str, raw_queries: list[dict]) -> str:
    """Step 2 of the drain: commit the unanswered queue to disk BEFORE
    the final flush, so a kill mid-drain loses nothing."""
    path = pending_path(ckpt_dir)
    _atomic_write_json(path, {"queries": raw_queries})
    obs.metrics().inc("serve.drained_queries", len(raw_queries))
    obs.instant("serve-drain-persist", path=path, n=len(raw_queries))
    return path


def clear_pending(ckpt_dir: str) -> None:
    try:
        os.remove(pending_path(ckpt_dir))
    except OSError:
        pass


def load_pending(ckpt_dir: str) -> list[dict]:
    """The previous process's unanswered queue ([] = clean shutdown).
    A corrupt file is quarantined and treated as empty — recovery must
    never block a restart."""
    path = pending_path(ckpt_dir)
    if not os.path.exists(path):
        return []
    try:
        with open(path) as f:
            payload = json.load(f)
        return list(payload.get("queries", []))
    except (OSError, ValueError) as e:
        LOG.warning("corrupt %s (%s: %s) — quarantined, skipping "
                    "recovery", path, type(e).__name__, e)
        obs.metrics().inc("serve.recover_corrupt")
        try:
            os.replace(path, path + ".corrupt")
        except OSError:
            pass
        return []


def recover(session: Session, ckpt_dir: str, *,
            coalesce: bool = True) -> int:
    """Re-execute the previous process's unanswered queue (if any);
    returns how many queries were recovered.  Runs synchronously at
    server start — the checkpoints make it cheap, and ``/readyz`` does
    not flip to ready until the debt is paid."""
    from .coalescer import execute_batch
    raw = load_pending(ckpt_dir)
    if not raw:
        return 0
    met = obs.metrics()
    queries = [Query.from_json(d) for d in raw]
    LOG.warning("recovering %d unanswered quer%s from %s",
                len(queries), "y" if len(queries) == 1 else "ies",
                pending_path(ckpt_dir))
    reports = execute_batch(session, queries, coalesce=coalesce)
    _atomic_write_json(
        recovered_path(ckpt_dir),
        {"reports": [r.results_json() for r in reports]})
    clear_pending(ckpt_dir)
    met.inc("serve.recovered", len(queries))
    obs.instant("serve-recover", n=len(queries))
    return len(queries)
