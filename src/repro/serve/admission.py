"""Admission control and backpressure for the serving tier.

Two gates, both decided BEFORE any engine work runs:

  * a bounded queue — at most ``max_queue`` admitted-but-unanswered
    requests; beyond that the server answers HTTP 429 with a
    ``Retry-After`` derived from the EWMA device-pass time (how long
    until a queue slot realistically frees up), so well-behaved clients
    back off instead of piling on;
  * a cost gate — :meth:`repro.api.Query.estimated_cost` prices each
    query from its spec alone, and anything over ``max_cost`` is shed
    immediately (the co-DSE "grid bomb" a public endpoint must survive:
    a 100x100 hardware grid times a million-candidate budget would hold
    the device pipeline for minutes).

Shedding is cheap and explicit: ``serve.shed`` counts every 429 (with a
``serve.shed_detail[reason=...]`` breakdown), and the invariant
``serve.shed + serve.completed == serve.admitted`` is CI-asserted — no
request admitted by this gate may ever vanish without a terminal
answer.
"""
from __future__ import annotations

import math
import threading

from .. import obs
from ..api import Query

# EWMA smoothing for the observed flush wall time (higher = snappier).
_ALPHA = 0.3


class AdmissionController:
    """Decides admit/shed for one server; thread-safe (HTTP handlers
    admit on the event loop, the flush worker reports wall times)."""

    def __init__(self, *, max_queue: int, max_cost: float | None):
        self.max_queue = int(max_queue)
        self.max_cost = None if max_cost is None else float(max_cost)
        self._lock = threading.Lock()
        self._ewma_flush_s = 0.05       # prior: one fast warm flush

    # -- decide --------------------------------------------------------

    def decide(self, query: Query, queue_depth: int) -> str | None:
        """None = admit; otherwise the shed reason (``"queue"`` /
        ``"cost"``)."""
        if queue_depth >= self.max_queue:
            return "queue"
        if self.max_cost is not None \
                and query.estimated_cost() > self.max_cost:
            return "cost"
        return None

    # -- backpressure hint ---------------------------------------------

    def note_flush(self, wall_s: float) -> None:
        """Fold one observed flush wall time into the EWMA the
        ``Retry-After`` hint is derived from."""
        with self._lock:
            self._ewma_flush_s += _ALPHA * (wall_s - self._ewma_flush_s)
        met = obs.metrics()
        met.gauge("serve.ewma_flush_s", round(self.ewma_flush_s, 4))
        met.observe_bucketed("serve.flush_s", wall_s)

    @property
    def ewma_flush_s(self) -> float:
        with self._lock:
            return self._ewma_flush_s

    def retry_after_s(self, queue_depth: int, max_batch: int) -> int:
        """Whole seconds until a retry is worth attempting: the queue's
        depth in flushes times the EWMA flush time, floored at 1s."""
        flushes = queue_depth / max(max_batch, 1) + 1.0
        return max(1, int(math.ceil(self.ewma_flush_s * flushes)))
