"""repro.serve — the hardened DSE-as-a-service tier over ``Session``.

An asyncio HTTP/JSON server (wire format = ``examples/queries.json``
queries, answers = ``Report.to_json()``) with continuous cross-request
coalescing: concurrent clients' layer queries accumulate per
(op-class, level-count) family and flush into ONE padded gene-tensor
device pass on a deadline-or-batch-size trigger.  Hardened end to end:

  * bounded admission queue + estimated-cost shedding (HTTP 429 with a
    ``Retry-After`` derived from the EWMA device-pass time);
  * per-request deadline budgets enforced cooperatively in the engine
    chunk loops — an expired request gets a terminal ``timeout``
    report, never a hang;
  * per-request isolation via ``Session``'s poisoned-batch fallback;
  * graceful draining shutdown (SIGTERM: stop admitting, persist the
    unanswered queue, flush in-flight families over sweep checkpoints)
    with bit-identical restart recovery;
  * ``/healthz`` ``/readyz`` ``/metricsz``, ``serve.*`` counters, and
    deterministic chaos drills (``slow@serve-flush``,
    ``crash@serve-worker``, ``kill@serve-drain``).
"""
from __future__ import annotations

from .admission import AdmissionController
from .coalescer import Coalescer, execute_batch
from .deadline import Deadline, batch_deadline_t
from .drain import (clear_pending, load_pending, pending_path,
                    persist_pending, recover, recovered_path,
                    save_observability)
from .loadgen import LoadgenResult, http_json, http_text, run_loadgen
from .server import DSEServer, ServeConfig

__all__ = [
    "AdmissionController", "Coalescer", "execute_batch",
    "Deadline", "batch_deadline_t",
    "clear_pending", "load_pending", "pending_path", "persist_pending",
    "recover", "recovered_path", "save_observability",
    "LoadgenResult", "http_json", "http_text", "run_loadgen",
    "DSEServer", "ServeConfig",
]
