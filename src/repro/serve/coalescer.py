"""Continuous cross-request coalescing: many clients, one device pass.

Admitted requests land in ONE buffer; a single flush worker drains it
in batches and answers every member through
:meth:`repro.api.Session.run_many` — which folds coalescible layer
queries into shared (op-class, level-count) family spaces and evaluates
ALL their candidates in one padded gene-tensor device pass.  The flush
trigger is deadline-or-batch-size: a batch goes as soon as it is full
(``max_batch``) or its oldest member has waited ``flush_interval_s``,
so a lone request pays at most one interval of latency while a burst
pays one compile for the whole burst.

All engine work happens on the ONE worker thread (the JAX dispatch path
is not thread-safe and device-serial anyway); the asyncio side only
parks futures.  :func:`execute_batch` is the single execution path
shared by the server's flush worker and the offline ``--file`` batch
CLI — which is what makes the offline run the oracle: the coalesced
server must answer bit-equal to ``repro.launch.query --file`` on the
same query set.

Determinism contract: a flush batch answers bit-equal to the offline
batch of the SAME query set — family spaces are built over the distinct
layer shapes of a batch (class-level tile padding), so the unit of
bit-equality is the flush, not the individual request.  The server's
flush trigger is tuned so a concurrent wave lands in one flush; the
drain/recovery path re-executes the exact persisted set, which is what
makes a killed drain resume bit-identically.

Fault sites (see ``resilience.faultinject``): ``serve-flush`` fires at
the head of every batch execution (``slow@serve-flush`` stretches a
flush past deadlines), ``serve-worker`` fires in the worker loop around
it (``crash@serve-worker`` exercises the answer-with-error-reports
isolation path).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Sequence

from .. import obs
from ..api import Query, Report, Session
from ..resilience import SweepKilled, cancel_scope, fault_point
from .deadline import Deadline, batch_deadline_t


def execute_batch(session: Session, queries: Sequence[Query], *,
                  coalesce: bool = True,
                  deadline_t: float | None = None) -> list[Report]:
    """THE batch execution path — server flushes and offline ``--file``
    batches both come through here, so their answers are bit-equal by
    construction.  ``deadline_t`` (absolute monotonic) bounds the whole
    pass via the engine's cooperative cancel scope."""
    fault_point("serve-flush")
    with cancel_scope(deadline_t):
        return session.run_many(list(queries), coalesce=coalesce)


class _Pending:
    """One admitted request parked between admission and its answer."""

    __slots__ = ("query", "raw", "deadline", "resolve", "t_enqueue",
                 "t_enqueue_pc", "rid")

    def __init__(self, query: Query, raw: dict[str, Any],
                 deadline: Deadline,
                 resolve: Callable[[Report | BaseException], None],
                 rid: str | None = None):
        self.query = query
        self.raw = raw                 # wire-format dict (round-trips,
        #                                unlike Query.describe())
        self.deadline = deadline
        self.resolve = resolve         # thread-safe, idempotent
        self.t_enqueue = time.monotonic()
        self.t_enqueue_pc = time.perf_counter()  # for retroactive spans
        self.rid = rid or obs.new_request_id()


class Coalescer:
    """The admission buffer plus its single flush worker thread."""

    def __init__(self, session: Session, *, max_batch: int,
                 flush_interval_s: float, coalesce: bool = True,
                 on_kill: Callable[[], None] | None = None,
                 on_flush_done: Callable[[float], None] | None = None,
                 flight_dir: str | None = None):
        self.session = session
        self.max_batch = int(max_batch)
        self.flush_interval_s = float(flush_interval_s)
        self.coalesce = coalesce
        self.on_kill = on_kill          # SweepKilled escape hatch
        self.on_flush_done = on_flush_done   # feeds the admission EWMA
        self.flight_dir = flight_dir    # crash-dump target (None = off)
        self._cv = threading.Condition()
        self._buf: list[_Pending] = []
        self._in_flight: list[_Pending] = []
        self._stop = False
        self._flush_now = False
        self._killed = False
        self._thread = threading.Thread(target=self._run,
                                        name="serve-flush", daemon=True)

    # -- producer side (event loop) ------------------------------------

    def start(self) -> None:
        self._thread.start()

    def put(self, item: _Pending) -> None:
        with self._cv:
            self._buf.append(item)
            obs.metrics().gauge("serve.queue_depth", len(self._buf))
            self._cv.notify()

    def depth(self) -> int:
        """Admitted-but-unanswered requests (buffered + in flight) —
        the quantity the admission queue bound applies to."""
        with self._cv:
            return len(self._buf) + len(self._in_flight)

    def unanswered(self) -> list[_Pending]:
        """Snapshot of every request that has not been answered yet —
        what a draining server persists before its final flush."""
        with self._cv:
            return list(self._in_flight) + list(self._buf)

    def drain(self, timeout_s: float = 60.0) -> bool:
        """Flush everything buffered and wait for the worker to go
        idle; returns False on timeout (or a killed worker)."""
        deadline = time.monotonic() + timeout_s
        with self._cv:
            self._flush_now = True
            self._cv.notify()
            while self._buf or self._in_flight:
                if self._killed:
                    return False
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                self._cv.wait(timeout=min(left, 0.1))
        return True

    def stop(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify()
        if self._thread is not threading.current_thread():
            self._thread.join(timeout=5.0)

    def mark_killed(self) -> None:
        """Simulated process death from outside the worker (e.g.
        ``kill@serve-drain`` on the event loop): the worker must flush
        NOTHING further — parked requests stay unanswered, exactly like
        a dead process, until drain persistence + recovery replay
        them."""
        with self._cv:
            self._killed = True
            self._cv.notify_all()

    # -- worker side ---------------------------------------------------

    def _due_locked(self) -> bool:
        if not self._buf:
            return False
        if self._flush_now or len(self._buf) >= self.max_batch:
            return True
        return (time.monotonic() - self._buf[0].t_enqueue
                >= self.flush_interval_s)

    def _run(self) -> None:
        met = obs.metrics()
        while True:
            with self._cv:
                while not self._stop and not self._killed \
                        and not self._due_locked():
                    # bounded wait so a lone request's age trigger fires
                    self._cv.wait(timeout=self.flush_interval_s / 2)
                if self._killed or (self._stop and not self._buf):
                    return
                batch = self._buf[:self.max_batch]
                del self._buf[:len(batch)]
                self._in_flight = batch
                met.gauge("serve.queue_depth", len(self._buf))
            try:
                self._flush(batch)
            except SweepKilled:
                # injected process death in the flush path: leave every
                # unanswered request parked (the drain persistence +
                # sweep checkpoints carry them across the restart)
                with self._cv:
                    self._killed = True
                    self._cv.notify_all()
                if self.on_kill is not None:
                    self.on_kill()
                return
            finally:
                if not self._killed:
                    with self._cv:
                        self._in_flight = []
                        self._cv.notify_all()

    def _flush(self, batch: list[_Pending]) -> None:
        met = obs.metrics()
        # already-expired members answer without engine work
        live: list[_Pending] = []
        for p in batch:
            if p.deadline.expired():
                # serve.timeouts is counted once, at the response path
                rep = p.deadline.timeout_report(p.query, where="queued")
                self._finalize_timing(p, rep, time.monotonic())
                p.resolve(rep)
            else:
                live.append(p)
        if not live:
            return
        t0 = time.monotonic()
        t0_pc = time.perf_counter()
        met.inc("serve.flushes")
        met.inc("serve.flush_queries", len(live))
        met.observe("serve.batch_size", len(live))
        rids = [p.rid for p in live]
        tracer = obs.current_tracer()
        if tracer is not None:
            # retroactive per-request queue-wait spans: enqueue -> flush
            for p in live:
                tracer.emit_between("queue-wait", "serve",
                                    p.t_enqueue_pc, t0_pc,
                                    {"rid": p.rid})
        # the request scope rides the contextvar into Session.run_many
        # and the engine chunk loops on this (the flush worker) thread —
        # every span/flight entry below is attributable to these rids
        with obs.request_scope(*rids):
            try:
                fault_point("serve-worker")
                with obs.span("flush", cat="serve", queries=len(live)):
                    reports = execute_batch(
                        self.session, [p.query for p in live],
                        coalesce=self.coalesce,
                        deadline_t=batch_deadline_t(
                            [p.deadline for p in live]))
            except SweepKilled:
                raise
            except Exception as e:  # noqa: BLE001 — answered per request
                # run_many already isolates engine failures; anything
                # that still escapes (e.g. crash@serve-worker before it,
                # or a poisoned batch with degrade off) answers every
                # member with an error report instead of taking the
                # server down
                met.inc("serve.flush_errors")
                obs.instant("serve-flush-error", queries=len(live),
                            error=type(e).__name__)
                obs.flight_record("error", "serve-flush-error",
                                  error=type(e).__name__,
                                  message=str(e)[:200],
                                  queries=len(live))
                if self.flight_dir:
                    try:
                        obs.dump_flight(self.flight_dir, "flush-error",
                                        error=type(e).__name__,
                                        request_ids=rids)
                    except Exception:  # noqa: BLE001 — crash path
                        pass
                now = time.monotonic()
                for p in live:
                    rep = Report.from_error(p.query, e)
                    self._finalize_timing(p, rep, t0, now=now)
                    p.resolve(rep)
                return
        wall = time.monotonic() - t0
        if self.on_flush_done is not None:
            self.on_flush_done(wall)
        for p, rep in zip(live, reports):
            self._finalize_timing(p, rep, t0)
            p.resolve(rep)

    @staticmethod
    def _finalize_timing(p: _Pending, rep: Report, t_flush: float,
                         now: float | None = None) -> None:
        """Re-finalize the session-stamped ``timing`` breakdown with the
        server-side view: per-request ``queue_wait`` (enqueue -> flush
        start) joins the engine phases, wall becomes enqueue -> answer,
        and ``other`` re-absorbs the residual so the phases still sum to
        the wall latency the client experienced."""
        now = time.monotonic() if now is None else now
        prev = rep.extras.get("timing") or {}
        phases = dict(prev.get("phases") or {})
        phases.pop("other", None)
        phases["queue_wait"] = max(0.0, t_flush - p.t_enqueue)
        rep.extras["timing"] = obs.timing_breakdown(
            now - p.t_enqueue, phases, request_id=p.rid)
