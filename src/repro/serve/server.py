"""The asyncio HTTP/JSON front of the DSE-as-a-service tier.

Stdlib-only (``asyncio.start_server`` + hand-rolled HTTP/1.1): one
long-lived :class:`repro.api.Session` behind four routes —

  ``POST /query``     one query in the ``examples/queries.json`` wire
                      format; answers ``Report.to_json()`` (HTTP 200 —
                      including terminal ``timeout``/``error`` kinds),
                      429 + ``Retry-After`` when shed, 400 on a
                      malformed spec.
  ``GET /healthz``    process liveness (always 200 while running).
  ``GET /readyz``     200 only when admitting (503 while recovering or
                      draining) — the load-balancer signal.
  ``GET /metricsz``   the full ``Session.metrics()`` snapshot plus a
                      ``serve`` block (queue depth, EWMA flush seconds,
                      draining flag).  Content-negotiated: JSON by
                      default, Prometheus text exposition when the
                      ``Accept`` header asks for ``text/plain`` /
                      ``openmetrics`` or with ``?format=prometheus``.

Request observability: every ``POST /query`` gets a request id (an
inbound ``X-Request-Id`` is honored, else one is minted), echoed in the
``X-Request-Id`` response header, carried by contextvar through the
coalescer into the engine chunk loops, and stamped on every trace span,
flight-recorder entry, and the report's ``extras.timing`` breakdown.
The always-on flight recorder dumps its ring on unhandled handler
errors, flush crashes, kills, SIGQUIT, and backstop timeouts.

Counter contract (CI-asserted):
``serve.shed + serve.completed == serve.admitted`` — every well-formed
query request either sheds with an explicit 429/503 or completes with a
terminal report; ``serve.timeouts``/``serve.errors`` are subsets of
completed.  Malformed requests count ``serve.bad_requests`` and
statically-illegal ones (pre-admission ``repro.analysis.speclint``)
count ``serve.speclint_rejected`` — both answer 400 and are outside
the invariant (never admitted).
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import logging
import os
import signal
import time
import urllib.parse
from typing import Any

from .. import obs
from ..api import Query, Report, Session
from ..resilience import SweepKilled, fault_point
from ..resilience.errors import SpecError
from .admission import AdmissionController
from .coalescer import Coalescer, _Pending
from .deadline import Deadline
from . import drain as drainmod

LOG = logging.getLogger("repro.serve")

_MAX_HEADER = 64 * 1024
_MAX_BODY = 8 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs of one server instance (defaults sized for the tiny-op CI
    smoke; production raises the queue/cost bounds)."""
    host: str = "127.0.0.1"
    port: int = 0                      # 0 = ephemeral (read server.port)
    max_queue: int = 64                # admitted-but-unanswered bound
    max_cost: float | None = 1e6       # estimated-cost shed gate
    max_batch: int = 16                # flush when this many buffered
    flush_interval_s: float = 0.05     # ... or when the oldest waited this
    default_deadline_s: float | None = 30.0
    grace_s: float = 2.0               # handler backstop past deadline
    coalesce: bool = True
    # kill@serve-drain semantics: a real server dies (os._exit — the
    # chaos drill wants actual process death mid-drain); in-process
    # tests flip this off so the "dead" server just stops, leaving its
    # pending file and sweep checkpoints for the restart to recover.
    exit_on_kill: bool = True
    # flight-recorder dump directory; None falls back to the session's
    # checkpoint dir, then $REPRO_FLIGHT_DIR / the system temp dir
    flight_dir: str | None = None


def _json_bytes(payload: Any) -> bytes:
    return json.dumps(payload).encode()


class DSEServer:
    """One serving instance: admission -> coalescer -> session."""

    def __init__(self, session: Session, config: ServeConfig | None = None):
        self.session = session
        self.config = config or ServeConfig()
        self.flight_dir = (self.config.flight_dir
                           or session.resilience.ckpt_dir
                           or obs.default_flight_dir())
        self.admission = AdmissionController(
            max_queue=self.config.max_queue,
            max_cost=self.config.max_cost)
        self.coalescer = Coalescer(
            session, max_batch=self.config.max_batch,
            flush_interval_s=self.config.flush_interval_s,
            coalesce=self.config.coalesce,
            on_kill=self._on_kill,
            on_flush_done=self.admission.note_flush,
            flight_dir=self.flight_dir)
        self.port: int | None = None
        self._server: asyncio.AbstractServer | None = None
        self._loop: asyncio.AbstractEventLoop | None = None
        self._ready = False
        self._draining = False
        self._killed = False
        self._stopped = asyncio.Event()

    # -- lifecycle -----------------------------------------------------

    async def start(self) -> None:
        """Recover the previous process's debt (if any), start the
        flush worker, bind the socket, flip ready."""
        self._loop = asyncio.get_running_loop()
        ckpt = self.session.resilience.ckpt_dir
        if ckpt:
            await self._loop.run_in_executor(
                None, lambda: drainmod.recover(
                    self.session, ckpt, coalesce=self.config.coalesce))
        self.coalescer.start()
        # span capture into the flight-recorder ring: on for the life of
        # the server so a crash dump always carries recent engine spans
        obs.enable_flight_spans(True)
        self._server = await asyncio.start_server(
            self._handle, self.config.host, self.config.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._ready = True
        obs.instant("serve-start", port=self.port)
        LOG.info("serving on %s:%d", self.config.host, self.port)

    def install_signal_handlers(self) -> None:
        """SIGTERM/SIGINT -> graceful drain; SIGQUIT -> flight dump
        (the live-postmortem poke, process keeps serving)."""
        assert self._loop is not None
        for sig in (signal.SIGTERM, signal.SIGINT):
            self._loop.add_signal_handler(
                sig, lambda: asyncio.ensure_future(self.drain()))
        self._loop.add_signal_handler(
            signal.SIGQUIT, lambda: self._dump_flight("sigquit"))

    async def wait_stopped(self) -> None:
        await self._stopped.wait()

    async def drain(self) -> None:
        """The SIGTERM path: stop admitting, persist the unanswered
        queue, flush in-flight families, exit.  ``kill@serve-drain``
        fires between persist and flush — the mid-drain death the
        restart recovery drill exercises."""
        if self._draining:
            return
        self._draining = True
        self._ready = False
        met = obs.metrics()
        met.inc("serve.drains")
        ckpt = self.session.resilience.ckpt_dir
        raw = [p.raw for p in self.coalescer.unanswered()]
        if ckpt and raw:
            drainmod.persist_pending(ckpt, raw)
        try:
            fault_point("serve-drain")
        except SweepKilled:
            LOG.warning("killed mid-drain (injected) — pending queue "
                        "and sweep checkpoints left for recovery")
            self._on_kill()
            await self._shutdown()
            return
        assert self._loop is not None
        ok = await self._loop.run_in_executor(None, self.coalescer.drain)
        if ok and ckpt:
            drainmod.clear_pending(ckpt)
        # the tracer and metrics snapshot must survive SIGTERM — flush
        # them next to the checkpoint/flight artifacts before exit
        try:
            drainmod.save_observability(ckpt or self.flight_dir,
                                        self.metrics())
        except Exception:  # noqa: BLE001 — drain must complete anyway
            LOG.exception("saving drain observability failed")
        obs.instant("serve-drain-done", flushed=len(raw), clean=ok)
        await self._shutdown()

    async def stop(self) -> None:
        """Immediate stop (tests); does NOT drain."""
        await self._shutdown()

    async def _shutdown(self) -> None:
        self._ready = False
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        self.coalescer.stop()
        obs.enable_flight_spans(False)
        self._stopped.set()

    def _dump_flight(self, reason: str, **info: Any) -> str | None:
        try:
            path = obs.dump_flight(self.flight_dir, reason, **info)
            LOG.warning("flight recorder dumped to %s (%s)", path, reason)
            return path
        except Exception:  # noqa: BLE001 — never compound a crash
            LOG.exception("flight dump failed")
            return None

    def _on_kill(self) -> None:
        """SweepKilled escaped a serve fault site: simulated process
        death."""
        self._killed = True
        self._ready = False
        self._dump_flight("killed")
        if self.config.exit_on_kill:
            os._exit(17)            # noqa: SLF001 — death IS the drill
        # in-process drill: the worker must answer nothing further
        self.coalescer.mark_killed()

    # -- introspection -------------------------------------------------

    def metrics(self) -> dict[str, Any]:
        snap = self.session.metrics()
        snap["serve"] = {
            "port": self.port,
            "ready": self._ready,
            "draining": self._draining,
            "queue_depth": self.coalescer.depth(),
            "ewma_flush_s": round(self.admission.ewma_flush_s, 4),
            "max_queue": self.config.max_queue,
            "max_batch": self.config.max_batch,
        }
        return snap

    # -- HTTP ----------------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            req = await asyncio.wait_for(_read_request(reader),
                                         timeout=30.0)
            if req is None:
                return
            method, path, query_string, req_headers, body = req
            status, headers, payload = await self._route(
                method, path, query_string, req_headers, body)
            await _respond(writer, status, headers, payload)
        except (asyncio.TimeoutError, ConnectionError,
                asyncio.IncompleteReadError):
            pass
        except Exception as e:  # noqa: BLE001 — a handler must never leak
            LOG.exception("request handler failed")
            obs.flight_record("error", "handler-error",
                              error=type(e).__name__,
                              message=str(e)[:200])
            self._dump_flight("handler-error", error=type(e).__name__)
            try:
                await _respond(writer, 500, {},
                               {"error": {"type": "internal"}})
            except Exception:  # noqa: BLE001
                pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:  # noqa: BLE001
                pass

    async def _route(self, method: str, path: str, query_string: str,
                     req_headers: dict[str, str], body: bytes
                     ) -> tuple[int, dict[str, str], Any]:
        if method == "GET" and path == "/healthz":
            return 200, {}, {"ok": True, "killed": self._killed}
        if method == "GET" and path == "/readyz":
            if self._ready and not self._draining:
                return 200, {}, {"ready": True}
            return 503, {}, {"ready": False,
                             "draining": self._draining}
        if method == "GET" and path == "/metricsz":
            if _wants_prometheus(query_string, req_headers):
                from ..obs.prom import CONTENT_TYPE, prometheus_text
                return 200, {"Content-Type": CONTENT_TYPE}, \
                    prometheus_text(self.metrics())
            return 200, {}, self.metrics()
        if method == "POST" and path == "/query":
            return await self._handle_query(req_headers, body)
        return 404, {}, {"error": {"type": "not_found", "path": path}}

    async def _handle_query(self, req_headers: dict[str, str],
                            body: bytes
                            ) -> tuple[int, dict[str, str], Any]:
        met = obs.metrics()
        met.inc("serve.requests")
        rid = (req_headers.get("x-request-id", "").strip()[:128]
               or obs.new_request_id())
        rid_h = {"X-Request-Id": rid}
        t_recv = time.monotonic()
        t_recv_pc = time.perf_counter()
        try:
            raw = json.loads(body.decode())
            query = Query.from_json(raw)
        except Exception as e:  # noqa: BLE001 — spec boundary
            met.inc("serve.bad_requests")
            msg = str(e).strip().splitlines()[0] if str(e).strip() else ""
            return 400, rid_h, {"error": {"type": type(e).__name__,
                                          "message": msg}}
        # pre-admission static lint (repro.analysis.speclint): a query
        # that cannot possibly produce a result — bad searched dims,
        # unconstructible space, statically infeasible buffer budget —
        # is rejected here, before it can burn a flush slot.  Counted
        # separately from bad_requests and OUTSIDE the shed/completed
        # ledger (like bad_requests, it was never admitted).
        try:
            query.lint()
        except SpecError as e:
            met.inc("serve.speclint_rejected")
            return 400, rid_h, {"error": {
                "type": "SpecError",
                "message": str(e).strip().splitlines()[0],
                "findings": e.details.get("findings", [])}}
        met.inc("serve.admitted")

        with obs.request_scope(rid):
            retry = {"Retry-After":
                     str(self.admission.retry_after_s(
                         self.coalescer.depth(), self.config.max_batch)),
                     **rid_h}
            if self._draining or not self._ready:
                met.inc("serve.shed")
                met.inc("serve.shed_detail", reason="draining")
                return 503, retry, {"error": {"type": "draining"}}
            reason = self.admission.decide(query, self.coalescer.depth())
            if reason is not None:
                met.inc("serve.shed")
                met.inc("serve.shed_detail", reason=reason)
                obs.instant("serve-shed", reason=reason, tag=query.tag)
                payload = {"error": {"type": "overloaded",
                                     "reason": reason,
                                     "retry_after_s":
                                         int(retry["Retry-After"])}}
                if reason == "cost":
                    payload["error"]["estimated_cost"] = \
                        query.estimated_cost()
                    payload["error"]["max_cost"] = self.admission.max_cost
                return 429, retry, payload

            deadline = Deadline.stamp(query,
                                      self.config.default_deadline_s)
            assert self._loop is not None
            fut: asyncio.Future = self._loop.create_future()
            self.coalescer.put(_Pending(query, raw, deadline,
                                        _resolver(self._loop, fut),
                                        rid=rid))
            remaining = deadline.remaining()
            timeout = None if remaining is None \
                else max(remaining, 0.0) + self.config.grace_s
            try:
                rep: Report = await asyncio.wait_for(fut, timeout)
            except asyncio.TimeoutError:
                # backstop: the engine is still holding the batch (or
                # died) past budget + grace; the client gets a terminal
                # timeout report NOW, whatever the worker is doing
                rep = deadline.timeout_report(query, where="in-flight")
                rep.extras["timing"] = obs.timing_breakdown(
                    time.monotonic() - t_recv, {}, request_id=rid)
                obs.flight_record("error", "backstop-timeout", rid=rid,
                                  tag=query.tag)
                try:
                    obs.flight_recorder().maybe_dump(
                        self.flight_dir, "backstop-timeout",
                        request_ids=[rid])
                except Exception:  # noqa: BLE001 — crash path
                    pass
            met.inc("serve.completed")
            if rep.kind == "timeout":
                met.inc("serve.timeouts")
            elif rep.kind == "error":
                met.inc("serve.errors")
            self._observe_slo(rep, rid, time.monotonic() - t_recv)
            tracer = obs.current_tracer()
            if tracer is not None:
                # the whole request as one retroactive span: receive ->
                # response, the parent row a Perfetto query follows
                tracer.emit_between(
                    "request", "serve", t_recv_pc, time.perf_counter(),
                    {"rid": rid, "kind": rep.kind, "tag": query.tag})
            return 200, rid_h, rep.to_json()

    @staticmethod
    def _observe_slo(rep: Report, rid: str, wall_s: float) -> None:
        """SLO histograms: end-to-end latency per report kind, plus the
        per-phase breakdown — both with the request id as exemplar, so
        a p99 bucket names a concrete request to go trace."""
        met = obs.metrics()
        met.observe_bucketed("serve.latency_s", wall_s, kind=rep.kind,
                             exemplar=rid)
        timing = rep.extras.get("timing")
        for phase, v in (timing or {}).get("phases", {}).items():
            met.observe_bucketed("serve.phase_s", v, phase=phase,
                                 exemplar=rid)


def _resolver(loop: asyncio.AbstractEventLoop, fut: asyncio.Future):
    """Thread-safe, idempotent future resolution from the flush
    worker."""
    def resolve(result) -> None:
        def _set() -> None:
            if fut.done():
                return             # handler already answered (timeout)
            if isinstance(result, BaseException):
                fut.set_exception(result)
            else:
                fut.set_result(result)
        try:
            loop.call_soon_threadsafe(_set)
        except RuntimeError:
            pass               # loop closed — the handler is long gone
    return resolve


def _wants_prometheus(query_string: str,
                      headers: dict[str, str]) -> bool:
    """Content negotiation for ``/metricsz``: JSON by default (every
    existing consumer), Prometheus text on explicit request."""
    fmt = urllib.parse.parse_qs(query_string).get("format", [""])[0]
    if fmt:
        return fmt == "prometheus"
    accept = headers.get("accept", "")
    return "text/plain" in accept or "openmetrics" in accept


async def _read_request(reader: asyncio.StreamReader
                        ) -> tuple[str, str, str, dict[str, str],
                                   bytes] | None:
    """Minimal HTTP/1.1 request parser: request line, headers,
    Content-Length body.  Returns ``(method, path, query_string,
    headers, body)`` with header names lowercased, or None on an empty
    connection."""
    line = await reader.readline()
    if not line.strip():
        return None
    parts = line.decode("latin1").split()
    if len(parts) < 2:
        raise ValueError(f"bad request line: {line!r}")
    method, target = parts[0].upper(), parts[1]
    headers: dict[str, str] = {}
    length = 0
    total = len(line)
    while True:
        h = await reader.readline()
        total += len(h)
        if total > _MAX_HEADER:
            raise ValueError("headers too large")
        if h in (b"\r\n", b"\n", b""):
            break
        name, _, value = h.decode("latin1").partition(":")
        headers[name.strip().lower()] = value.strip()
        if name.strip().lower() == "content-length":
            length = int(value.strip())
    if length > _MAX_BODY:
        raise ValueError("body too large")
    body = await reader.readexactly(length) if length else b""
    path, _, query_string = target.partition("?")
    return method, path, query_string, headers, body


async def _respond(writer: asyncio.StreamWriter, status: int,
                   headers: dict[str, str], payload: Any) -> None:
    reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
              429: "Too Many Requests", 500: "Internal Server Error",
              503: "Service Unavailable"}.get(status, "OK")
    headers = dict(headers)
    if isinstance(payload, (str, bytes)):
        body = payload.encode() if isinstance(payload, str) else payload
        ctype = headers.pop("Content-Type",
                            "text/plain; charset=utf-8")
    else:
        body = _json_bytes(payload)
        ctype = headers.pop("Content-Type", "application/json")
    head = [f"HTTP/1.1 {status} {reason}",
            f"Content-Type: {ctype}",
            f"Content-Length: {len(body)}",
            "Connection: close"]
    head += [f"{k}: {v}" for k, v in headers.items()]
    writer.write(("\r\n".join(head) + "\r\n\r\n").encode() + body)
    await writer.drain()
