"""Async load generator for the serving tier (client side of the wire).

Drives N concurrent clients against a running :class:`DSEServer`, each
posting queries round-robin from a fixed set, and accounts for EVERY
request: terminal report kinds (layer/network/timeout/error), shed
statuses (429/503), bad requests, and transport failures — the
acceptance bar is zero requests without a terminal status.  Latency is
recorded per request; the summary carries p50/p99 and queries/s, which
is what BENCH_serve and the CI smoke assert on.

Stdlib-only: raw ``asyncio.open_connection`` HTTP/1.1 with
``Connection: close`` (one connection per request — the worst,
simplest client behaviour a public endpoint must absorb).
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import time
from typing import Any, Sequence


async def _http_raw(host: str, port: int, method: str, path: str,
                    payload: Any = None, *,
                    headers: dict[str, str] | None = None,
                    timeout: float = 60.0) -> tuple[int, bytes]:
    """One HTTP exchange; returns (status, raw response body)."""
    async def _go() -> tuple[int, bytes]:
        reader, writer = await asyncio.open_connection(host, port)
        try:
            body = b"" if payload is None else json.dumps(payload).encode()
            head = [f"{method} {path} HTTP/1.1", f"Host: {host}",
                    "Content-Type: application/json",
                    f"Content-Length: {len(body)}",
                    "Connection: close"]
            head += [f"{k}: {v}" for k, v in (headers or {}).items()]
            writer.write(("\r\n".join(head) + "\r\n\r\n").encode() + body)
            await writer.drain()
            raw = await reader.read()
            status = int(raw.split(b" ", 2)[1])
            _, _, resp = raw.partition(b"\r\n\r\n")
            return status, resp
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except Exception:  # noqa: BLE001
                pass
    return await asyncio.wait_for(_go(), timeout)


async def http_json(host: str, port: int, method: str, path: str,
                    payload: Any = None, *,
                    headers: dict[str, str] | None = None,
                    timeout: float = 60.0) -> tuple[int, Any]:
    """One HTTP exchange; returns (status, decoded JSON body)."""
    status, resp = await _http_raw(host, port, method, path, payload,
                                   headers=headers, timeout=timeout)
    return status, (json.loads(resp) if resp.strip() else None)


async def http_text(host: str, port: int, method: str, path: str, *,
                    headers: dict[str, str] | None = None,
                    timeout: float = 60.0) -> tuple[int, str]:
    """One HTTP exchange; returns (status, text body) — for the
    Prometheus ``/metricsz`` exposition."""
    status, resp = await _http_raw(host, port, method, path,
                                   headers=headers, timeout=timeout)
    return status, resp.decode("utf-8", "replace")


def _percentile(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[i]


@dataclasses.dataclass
class LoadgenResult:
    n_requests: int
    statuses: dict[int, int]
    kinds: dict[str, int]              # report kind counts (status 200)
    transport_errors: int              # no HTTP response at all
    latencies_s: list[float]
    wall_s: float
    reports: list[Any]                 # (query index, report json) pairs

    @property
    def n_terminal(self) -> int:
        """Requests that got an explicit terminal status (any HTTP
        response counts — 200 report, 429/503 shed, 400 reject)."""
        return sum(self.statuses.values())

    def summary(self) -> dict[str, Any]:
        lat = sorted(self.latencies_s)
        return {
            "n_requests": self.n_requests,
            "n_terminal": self.n_terminal,
            "transport_errors": self.transport_errors,
            "statuses": {str(k): v for k, v in
                         sorted(self.statuses.items())},
            "kinds": dict(sorted(self.kinds.items())),
            "p50_s": round(_percentile(lat, 0.50), 4),
            "p99_s": round(_percentile(lat, 0.99), 4),
            "wall_s": round(self.wall_s, 3),
            "queries_per_s": round(self.n_requests / self.wall_s, 2)
            if self.wall_s > 0 else 0.0,
        }


async def run_loadgen(host: str, port: int,
                      queries: Sequence[dict], *,
                      clients: int = 10,
                      requests_per_client: int = 4,
                      timeout: float = 120.0) -> LoadgenResult:
    """N concurrent clients, each posting ``requests_per_client``
    queries round-robin from ``queries`` (wire-format dicts)."""
    statuses: dict[int, int] = {}
    kinds: dict[str, int] = {}
    latencies: list[float] = []
    reports: list[Any] = []
    transport_errors = 0
    lock = asyncio.Lock()

    async def client(ci: int) -> None:
        nonlocal transport_errors
        for ri in range(requests_per_client):
            # offset by client id so every concurrent wave spans the
            # whole query set (not N copies of one query)
            qi = (ci + ri) % len(queries)
            # deterministic client-minted request id — the server honors
            # it, so traces/flight dumps are attributable to (client,
            # request) without parsing response headers
            rid = f"lg-{ci:04d}-{ri:03d}"
            t0 = time.monotonic()
            try:
                status, body = await http_json(
                    host, port, "POST", "/query", queries[qi],
                    headers={"X-Request-Id": rid}, timeout=timeout)
            except Exception:  # noqa: BLE001 — accounted, not raised
                async with lock:
                    transport_errors += 1
                continue
            dt = time.monotonic() - t0
            async with lock:
                statuses[status] = statuses.get(status, 0) + 1
                latencies.append(dt)
                if status == 200 and isinstance(body, dict):
                    kind = body.get("kind", "?")
                    kinds[kind] = kinds.get(kind, 0) + 1
                    reports.append((qi, body))

    t0 = time.monotonic()
    await asyncio.gather(*(client(i) for i in range(clients)))
    wall = time.monotonic() - t0
    return LoadgenResult(
        n_requests=clients * requests_per_client, statuses=statuses,
        kinds=kinds, transport_errors=transport_errors,
        latencies_s=latencies, wall_s=wall, reports=reports)
