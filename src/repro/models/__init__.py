from . import registry
from .param import (ParamSpec, abstract_params, axes_tree, count_params,
                    init_params)

__all__ = ["registry", "ParamSpec", "abstract_params", "axes_tree",
           "count_params", "init_params"]
