"""Linear-recurrence blocks: RWKV-6 (Finch) and Mamba-2 (SSD), built on one
chunked linear-attention core.

Recurrence (per head, state S ∈ R^{K×V}):

    S_t = diag(w_t) · S_{t-1} + k_t v_t^T
    o_t = r_t · S_{t-1} + (r_t · (u ⊙ k_t)) v_t      (RWKV-6: pre-update + bonus)
    o_t = r_t · S_t                                   (Mamba-2: post-update)

The chunked form processes T in blocks of ``chunk``: an inter-chunk term
against the carried state and an intra-chunk decay-weighted attention
matrix — O(T·c) memory, scan over T/c chunks.  This is also the reference
oracle for the ``linear_scan`` Pallas kernel.

Per-step log-decays are clamped at -60/chunk: contributions below e^-60
are exactly 0 in fp32, and the clamp keeps the standard two-sided
exp factorization inside fp32 range.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .param import ParamSpec

NEG_CLAMP = 60.0


def chunked_linear_attn(r, k, v, log_w, *, u=None, state0=None,
                        chunk: int = 64, post_update: bool = False,
                        unroll: bool = False):
    """r/k/log_w: (B, T, H, K); v: (B, T, H, V).  Returns (o, state_T) with
    o: (B, T, H, V), state: (B, H, K, V)."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    c = min(chunk, T)
    nc = T // c
    assert nc * c == T, f"T={T} not divisible by chunk={c}"
    f32 = jnp.float32
    r, k, v = r.astype(f32), k.astype(f32), v.astype(f32)
    lw = jnp.clip(log_w.astype(f32), -NEG_CLAMP / c, 0.0)
    if state0 is None:
        state0 = jnp.zeros((B, H, K, V), f32)

    rc = r.reshape(B, nc, c, H, K)
    kc = k.reshape(B, nc, c, H, K)
    vc = v.reshape(B, nc, c, H, V)
    lwc = lw.reshape(B, nc, c, H, K)
    tri = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :]) if not \
        post_update else (jnp.arange(c)[:, None] >= jnp.arange(c)[None, :])

    def body(S, xs):
        rb, kb, vb, lwb = xs                     # (B, c, H, *)
        P = jnp.cumsum(lwb, axis=1)              # inclusive cumulative decay
        Pq = P if post_update else P - lwb       # decay seen by the query
        q_eff = rb * jnp.exp(Pq)
        k_eff = kb * jnp.exp(-P)
        inter = jnp.einsum("bchk,bhkv->bchv", q_eff, S)
        A = jnp.einsum("bihk,bjhk->bhij", q_eff, k_eff)
        A = A * tri[None, None]
        if u is not None:                        # RWKV-6 current-token bonus
            diag = jnp.einsum("bchk,hk,bchk->bch", rb, u.astype(f32), kb)
            idx = jnp.arange(c)
            A = A.at[:, :, idx, idx].add(jnp.moveaxis(diag, 1, 2))
        intra = jnp.einsum("bhij,bjhv->bihv", A, vb)
        o = inter + intra
        decay_all = jnp.exp(P[:, -1])            # (B, H, K)
        S_new = S * decay_all[..., None] + jnp.einsum(
            "bchk,bchv->bhkv", kb * jnp.exp(P[:, -1:] - P), vb)
        return S_new, o

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rc, kc, vc, lwc))
    state, os_ = jax.lax.scan(body, state0, xs, unroll=unroll)
    o = jnp.moveaxis(os_, 0, 1).reshape(B, T, H, V)
    return o, state


def linear_attn_step(r, k, v, log_w, *, u=None, state=None,
                     post_update: bool = False):
    """Single-token decode step.  r/k/log_w: (B, H, K); v: (B, H, V);
    state: (B, H, K, V)."""
    f32 = jnp.float32
    r, k, v = r.astype(f32), k.astype(f32), v.astype(f32)
    w = jnp.exp(jnp.clip(log_w.astype(f32), -NEG_CLAMP, 0.0))
    kv = k[..., :, None] * v[..., None, :]       # (B, H, K, V)
    if post_update:
        state = state * w[..., None] + kv
        o = jnp.einsum("bhk,bhkv->bhv", r, state)
    else:
        o = jnp.einsum("bhk,bhkv->bhv", r, state)
        if u is not None:
            o = o + jnp.einsum("bhk,bhkv->bhv", r * u.astype(f32)[None], kv)
        state = state * w[..., None] + kv
    return o, state


# ----------------------------------------------------------------------
# RWKV-6 block
# ----------------------------------------------------------------------

LORA = 32


def rwkv6_specs(cfg: ModelConfig, stacked: int) -> dict:
    d = cfg.d_model
    L, lx = (stacked,), ("layers",)
    def mat(shape, axes, **kw):
        return ParamSpec(L + shape, lx + axes, **kw)
    return {
        "mix": mat((5, d), (None, "embed"), init="zeros"),   # r,k,v,w,g lerp
        "wr": mat((d, d), ("embed", "heads_flat")),
        "wk": mat((d, d), ("embed", "heads_flat")),
        "wv": mat((d, d), ("embed", "heads_flat")),
        "wg": mat((d, d), ("embed", "heads_flat")),
        "wo": mat((d, d), ("heads_flat", "embed")),
        "w_base": mat((d,), ("embed",), init="zeros"),
        "w_lora_a": mat((d, LORA), ("embed", None), scale=0.01),
        "w_lora_b": mat((LORA, d), (None, "embed"), scale=0.01),
        "u": mat((d,), ("embed",), init="zeros"),
        "ln_x_scale": mat((d,), ("embed",), init="ones"),
        # channel mix (FFN)
        "cm_mix": mat((2, d), (None, "embed"), init="zeros"),
        "cm_k": mat((d, cfg.d_ff), ("embed", "mlp")),
        "cm_v": mat((cfg.d_ff, d), ("mlp", "embed")),
        "cm_r": mat((d, d), ("embed", "embed_out")),
    }


def _token_shift(x, prev):
    """prev: (B, 1, D) last token of the previous segment (zeros at start).
    Returns x_{t-1} aligned with x_t, and the new carry."""
    shifted = jnp.concatenate([prev, x[:, :-1]], axis=1)
    return shifted, x[:, -1:]


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu.astype(x.dtype)


def rwkv6_time_mix(p, x, x_prev, cfg: ModelConfig, *, state=None,
                   decode=False):
    """Returns (y, (new_state, new_x_carry))."""
    B = x.shape[0]
    d = cfg.d_model
    H, K = cfg.n_heads, d // cfg.n_heads
    if decode:
        xs = x_prev  # (B, 1, D) carry
        carry = x
    else:
        xs, carry = _token_shift(x, x_prev)
    mix = p["mix"].astype(jnp.float32)
    xr = _lerp(x, xs, mix[0])
    xk = _lerp(x, xs, mix[1])
    xv = _lerp(x, xs, mix[2])
    xw = _lerp(x, xs, mix[3])
    xg = _lerp(x, xs, mix[4])
    r = (xr @ p["wr"]).reshape(B, -1, H, K)
    k = (xk @ p["wk"]).reshape(B, -1, H, K)
    v = (xv @ p["wv"]).reshape(B, -1, H, K)
    g = xg @ p["wg"]
    ww = p["w_base"].astype(jnp.float32) + \
        (xw.astype(jnp.float32) @ p["w_lora_a"].astype(jnp.float32)
         ) @ p["w_lora_b"].astype(jnp.float32)
    log_w = -jnp.exp(ww.reshape(B, -1, H, K))     # data-dependent decay < 0
    u = p["u"].astype(jnp.float32).reshape(H, K)

    if decode:
        o, new_state = linear_attn_step(
            r[:, 0], k[:, 0], v[:, 0], log_w[:, 0], u=u, state=state)
        o = o[:, None]
    else:
        o, new_state = chunked_linear_attn(
            r, k, v, log_w, u=u, state0=state, chunk=cfg.chunk_size,
            unroll=cfg.scan_unroll)
    # per-head group norm
    of = o.reshape(B, -1, H, K).astype(jnp.float32)
    of = of * jax.lax.rsqrt(jnp.mean(of * of, -1, keepdims=True) + 1e-6)
    of = of.reshape(B, -1, d) * p["ln_x_scale"].astype(jnp.float32)
    y = (of * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype) @ p["wo"]
    return y, (new_state, carry)


def rwkv6_channel_mix(p, x, x_prev, cfg: ModelConfig, decode=False):
    if decode:
        xs, carry = x_prev, x
    else:
        xs, carry = _token_shift(x, x_prev)
    mix = p["cm_mix"].astype(jnp.float32)
    xk = _lerp(x, xs, mix[0])
    xr = _lerp(x, xs, mix[1])
    h = jnp.maximum(xk @ p["cm_k"], 0.0) ** 2
    y = (h @ p["cm_v"]) * jax.nn.sigmoid((xr @ p["cm_r"]).astype(jnp.float32)
                                         ).astype(x.dtype)
    return y, carry


# ----------------------------------------------------------------------
# Mamba-2 block
# ----------------------------------------------------------------------

def mamba2_specs(cfg: ModelConfig, stacked: int) -> dict:
    d = cfg.d_model
    di = d * cfg.ssm_expand
    N, H = cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * N
    L, lx = (stacked,), ("layers",)
    def mat(shape, axes, **kw):
        return ParamSpec(L + shape, lx + axes, **kw)
    return {
        # separate projections (z / x+B+C / dt) so each output width is
        # tensor-parallel-divisible (a fused in_proj of width 2di+2N+H is
        # not divisible by the 16-way model axis for zamba2's dims)
        "w_z": mat((d, di), ("embed", "mlp")),
        "w_xbc": mat((d, conv_ch), ("embed", "mlp")),
        "w_dt": mat((d, H), ("embed", "heads_flat")),
        "conv_w": mat((cfg.conv_width, conv_ch), (None, "mlp"),
                      scale=cfg.conv_width ** -0.5),
        "conv_b": mat((conv_ch,), ("mlp",), init="zeros"),
        "a_log": mat((H,), ("heads_flat",), init="zeros"),
        "dt_bias": mat((H,), ("heads_flat",), init="zeros"),
        "d_skip": mat((H,), ("heads_flat",), init="ones"),
        "norm_scale": mat((di,), ("mlp",), init="ones"),
        "out_proj": mat((di, d), ("mlp", "embed")),
    }


def _causal_conv(x, w, b, state=None):
    """x: (B, T, C), w: (W, C) depthwise.  state: (B, W-1, C) carry.
    Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else state
    return y + b, new_state


def mamba2_block(p, x, cfg: ModelConfig, *, state=None, conv_state=None,
                 decode=False):
    """Returns (y, (ssm_state, conv_state))."""
    B, T, d = x.shape
    di = d * cfg.ssm_expand
    N, H = cfg.ssm_state, cfg.ssm_heads
    P = di // H
    z = x @ p["w_z"]
    xbc = x @ p["w_xbc"]
    dt = x @ p["w_dt"]
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xs, Bt, Ct = jnp.split(xbc, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,T,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))               # (H,)
    log_w = (dt * a)[..., None] * jnp.ones((1, 1, 1, N))       # (B,T,H,N)

    v = (xs.reshape(B, T, H, P).astype(jnp.float32)
         * dt[..., None])                                      # dt·x
    r = jnp.broadcast_to(Ct[:, :, None, :], (B, T, H, N))
    k = jnp.broadcast_to(Bt[:, :, None, :], (B, T, H, N))

    if decode:
        o, state = linear_attn_step(r[:, 0], k[:, 0], v[:, 0], log_w[:, 0],
                                    state=state, post_update=True)
        o = o[:, None]
    else:
        o, state = chunked_linear_attn(r, k, v, log_w, state0=state,
                                       chunk=cfg.chunk_size,
                                       post_update=True,
                                       unroll=cfg.scan_unroll)
    y = o + xs.reshape(B, T, H, P).astype(jnp.float32) \
        * p["d_skip"].astype(jnp.float32)[:, None]
    y = y.reshape(B, T, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(y * y, -1, keepdims=True) + 1e-6)
    y = (y * p["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    return y @ p["out_proj"], (state, conv_state)
