"""Family dispatch: one uniform API over all assigned architectures.

    specs(cfg)                         -> ParamSpec tree
    loss_fn(params, batch, cfg)        -> scalar
    prefill(params, batch, cfg, L)     -> (last_logits, cache)
    decode_step(params, batch, cache, cfg) -> (logits, cache)
"""
from __future__ import annotations

from ..configs.base import ModelConfig
from . import encdec, transformer
from .param import (SpecTree, abstract_params, axes_tree, count_params,
                    init_params)


def specs(cfg: ModelConfig) -> SpecTree:
    if cfg.is_encdec:
        return encdec.encdec_specs(cfg)
    return transformer.lm_specs(cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    if cfg.is_encdec:
        return encdec.loss_fn(params, batch, cfg)
    return transformer.loss_fn(params, batch, cfg)


def prefill(params, batch, cfg: ModelConfig, max_len: int):
    if cfg.is_encdec:
        return encdec.prefill(params, batch, cfg, max_len)
    return transformer.prefill(params, batch, cfg, max_len)


def decode_step(params, batch, cache, cfg: ModelConfig):
    if cfg.is_encdec:
        return encdec.decode_step(params, batch, cache, cfg)
    return transformer.decode_step(params, batch, cache, cfg)


__all__ = ["specs", "loss_fn", "prefill", "decode_step", "abstract_params",
           "axes_tree", "count_params", "init_params"]
