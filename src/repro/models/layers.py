"""Transformer building blocks: norms, RoPE, GQA attention (chunked online-
softmax for train/prefill, cache-based for decode), MLPs.

Everything is functional: ``f(params, x, cfg, ...) -> y``.  Code is written
in the global view — under ``jit`` with sharded inputs the SPMD partitioner
turns the einsums into the tensor/data-parallel collectives the MAESTRO
mapper predicts (see ``core/mapper.py``).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .param import ParamSpec


# ----------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------

def norm_specs(cfg: ModelConfig, stacked: int | None = None) -> dict:
    lead = (stacked,) if stacked else ()
    lax_ = ("layers",) if stacked else ()
    if cfg.norm == "ln_nonparam":
        return {}
    out = {"scale": ParamSpec(lead + (cfg.d_model,), lax_ + ("embed",),
                              init="ones")}
    if cfg.norm == "ln":
        out["bias"] = ParamSpec(lead + (cfg.d_model,), lax_ + ("embed",),
                                init="zeros")
    return out


def apply_norm(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rms":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    if cfg.norm == "ln":
        y = y * params["scale"].astype(jnp.float32) \
            + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# Attention
# ----------------------------------------------------------------------

def attention_specs(cfg: ModelConfig, stacked: int | None = None,
                    d_kv_src: int | None = None) -> dict:
    """QKV/out projection specs.  ``d_kv_src`` overrides the K/V source
    width (cross-attention)."""
    d, hd = cfg.d_model, cfg.head_dim_
    dkv = d_kv_src or d
    lead = (stacked,) if stacked else ()
    lax_ = ("layers",) if stacked else ()
    # explicit fan-in scales: the (d, H, hd) layout defeats the last-but-
    # one-dim heuristic (it would read H as the fan-in)
    out = {
        "wq": ParamSpec(lead + (d, cfg.n_heads, hd),
                        lax_ + ("embed", "heads", "qkv"),
                        scale=d ** -0.5),
        "wk": ParamSpec(lead + (dkv, cfg.n_kv_heads, hd),
                        lax_ + ("embed", "kv_heads", "qkv"),
                        scale=dkv ** -0.5),
        "wv": ParamSpec(lead + (dkv, cfg.n_kv_heads, hd),
                        lax_ + ("embed", "kv_heads", "qkv"),
                        scale=dkv ** -0.5),
        "wo": ParamSpec(lead + (cfg.n_heads, hd, d),
                        lax_ + ("heads", "qkv", "embed"),
                        scale=(cfg.n_heads * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        out["bq"] = ParamSpec(lead + (cfg.n_heads, hd),
                              lax_ + ("heads", "qkv"), init="zeros")
        out["bk"] = ParamSpec(lead + (cfg.n_kv_heads, hd),
                              lax_ + ("kv_heads", "qkv"), init="zeros")
        out["bv"] = ParamSpec(lead + (cfg.n_kv_heads, hd),
                              lax_ + ("kv_heads", "qkv"), init="zeros")
    return out


def _project_qkv(params, xq, xkv, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", xq, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return q, k, v


import os as _os


def _kernel_backend() -> str | None:
    """'pallas' on TPU, 'interpret' when forced (tests), else None."""
    if _os.environ.get("REPRO_USE_PALLAS") == "interpret":
        return "interpret"
    if jax.default_backend() == "tpu":
        return "pallas"
    return None


def _gqa_scores_full(q, k, v, causal: bool, q_offset, chunk: int,
                     unroll: bool = False):
    """Chunked online-softmax attention (flash-style, pure jnp).

    q: (B, Sq, Hq, D), k/v: (B, Sk, Hkv, D).  Scans over query blocks so
    peak memory is O(Sq_block × Sk) instead of O(Sq × Sk).  This is also
    the reference oracle for the Pallas flash kernel.

    K/V are repeated up to Hq heads (GQA): keeping every tensor on the
    full head dim lets the SPMD partitioner shard heads over 'model' even
    when Hkv < model-axis width — a (Hkv, group) reshape would force score
    replication."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = D ** -0.5
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    from ..distributed.autosharding import constrain
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "heads", None))
    v = constrain(v, ("batch", None, "heads", None))
    # largest block count <= Sq/chunk that divides Sq (frontends can make
    # Sq a non-multiple of the chunk, e.g. 576 patches + 4096 tokens)
    nblk = max(1, Sq // chunk)
    while Sq % nblk:
        nblk -= 1
    blk = Sq // nblk
    qb = q.reshape(B, nblk, blk, Hq, D)
    kT = k.astype(jnp.float32)
    vT = v.astype(jnp.float32)
    kv_pos = jnp.arange(Sk)

    def body(_, qi):
        qblk, idx = qi
        s = jnp.einsum("bqhd,bkhd->bhqk", qblk.astype(jnp.float32),
                       kT) * scale
        if causal:
            qpos = q_offset + idx * blk + jnp.arange(blk)
            mask = kv_pos[None, :] <= qpos[:, None]          # (blk, Sk)
            s = jnp.where(mask[None, None], s, -1e30)
        m = jnp.max(s, -1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, -1, keepdims=True)
        o = jnp.einsum("bhqk,bkhd->bhqd", p / jnp.maximum(l, 1e-30), vT)
        return None, o

    qb_t = jnp.moveaxis(qb, 1, 0)                            # (nblk, B, ...)
    _, outs = jax.lax.scan(body, None, (qb_t, jnp.arange(nblk)),
                           unroll=unroll)
    out = jnp.moveaxis(outs, 0, 1)                           # (B,nblk,h,blk,d)
    out = jnp.transpose(out, (0, 1, 3, 2, 4)).reshape(B, Sq, Hq, D)
    return out.astype(q.dtype)


def _gqa_decode(q, k_cache, v_cache, length):
    """One-step decode: q (B, 1, Hq, D) vs cache (B, Smax, Hkv, D); only
    the first ``length`` cache entries are valid.  K/V repeated to Hq
    heads (see _gqa_scores_full).

    The cache stays in its storage dtype with fp32 *accumulation*
    (preferred_element_type) — an explicit .astype(f32) would materialize
    (and, with a sequence-sharded cache, all-gather) a 2× copy; §Perf-B
    measured 4.3 GB/layer of exactly that."""
    B, _, Hq, D = q.shape
    _, Sk, Hkv, _ = k_cache.shape
    g = Hq // Hkv
    if g > 1:
        k_cache = jnp.repeat(k_cache, g, axis=2)
        v_cache = jnp.repeat(v_cache, g, axis=2)
    qb = q.reshape(B, Hq, D).astype(k_cache.dtype)
    s = jnp.einsum("bhd,bkhd->bhk", qb, k_cache,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    mask = jnp.arange(Sk)[None, None, :] < length
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # p stays f32: the v upcast is a local elementwise convert (cheap and
    # sharding-preserving), unlike the cache-wide f32 copy removed above
    o = jnp.einsum("bhk,bkhd->bhd", p, v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, Hq, D).astype(q.dtype)


def attention(params: dict, x: jnp.ndarray, cfg: ModelConfig, *,
              positions: jnp.ndarray, causal: bool = True,
              xkv: jnp.ndarray | None = None,
              cache: dict | None = None,
              decode: bool = False) -> tuple[jnp.ndarray, dict | None]:
    """Returns (output, new_cache).  Modes:

    * train/prefill (``decode=False``): full-sequence chunked attention;
      if ``cache`` is given it is filled (prefill).
    * decode: ``x`` is (B, 1, D); reads/updates ``cache`` at
      ``cache['length']``.
    * cross-attention: pass ``xkv`` (encoder output) and ``causal=False``.
    """
    src = xkv if xkv is not None else x
    q, k, v = _project_qkv(params, x, src, cfg)
    if cfg.pos == "rope" and xkv is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    kb = _kernel_backend()
    if kb and not decode and cache is None and \
            q.shape[1] % 128 == 0 and k.shape[1] % 128 == 0:
        # Pallas flash-attention kernel on TPU (interpret-forced in tests)
        from ..kernels.flash_attention import flash_attention
        out = flash_attention(q, k, v, causal=causal and xkv is None,
                              interpret=(kb == "interpret"))
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), None

    new_cache = None
    if decode:
        assert cache is not None
        from ..distributed.autosharding import constrain
        length = cache["length"]
        # one-hot select update instead of dynamic_update_slice: a DUS at
        # a dynamic offset on a sequence-sharded dim forces SPMD to
        # all-gather the whole cache (§Perf-B); the select partitions.
        # The cache sharding is pinned on both sides of the select so the
        # propagation reshards the (B,1,Hkv,D) *new* entry, not the cache.
        kv_axes = ("batch", "kv_seq", "kv_heads", "qkv")
        sel = (jnp.arange(cache["k"].shape[1]) == length)[None, :, None,
                                                          None]
        k_cache = jnp.where(sel, k.astype(cache["k"].dtype),
                            constrain(cache["k"], kv_axes))
        v_cache = jnp.where(sel, v.astype(cache["v"].dtype),
                            constrain(cache["v"], kv_axes))
        k_cache = constrain(k_cache, kv_axes)
        v_cache = constrain(v_cache, kv_axes)
        # Heads and cache-sequence both want the 'model' axis; the
        # partitioner must gather one side.  Replicating the (B,1,Hq,D)
        # query costs ~100 KB; gathering the cache costs GBs — force the
        # cheap side (flash-decode: scores stay sequence-sharded, the
        # softmax combine is a tiny all-reduce).
        q = constrain(q, ("batch", None, None, None))
        out = _gqa_decode(q, k_cache, v_cache, length + 1)
        out = constrain(out, ("batch", None, None, None))
        new_cache = {"k": k_cache, "v": v_cache, "length": length + 1}
    else:
        out = _gqa_scores_full(q, k, v, causal and xkv is None,
                               q_offset=0, chunk=cfg.chunk_size,
                               unroll=cfg.scan_unroll)
        if cache is not None:
            Smax = cache["k"].shape[1]
            pad = [(0, 0), (0, Smax - k.shape[1]), (0, 0), (0, 0)]
            new_cache = {
                "k": jnp.pad(k.astype(cache["k"].dtype), pad),
                "v": jnp.pad(v.astype(cache["v"].dtype), pad),
                "length": jnp.asarray(k.shape[1], jnp.int32),
            }
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


def make_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_layers: int | None = None, dtype=jnp.bfloat16) -> dict:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
    if n_layers is not None:
        shape = (n_layers,) + shape
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "length": (jnp.zeros((n_layers,), jnp.int32)
                   if n_layers is not None else jnp.asarray(0, jnp.int32)),
    }


def kv_cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                   n_layers: int | None = None, *, shard_seq: bool = False,
                   dtype=jnp.bfloat16):
    """Abstract cache + logical axes for the dry-run.  ``shard_seq`` puts
    the sequence axis on the data mesh axis (long-context decode)."""
    seq_ax = "kv_seq" if shard_seq else None
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
    axes = ("batch", seq_ax, "kv_heads", "qkv")
    if n_layers is not None:
        shape = (n_layers,) + shape
        axes = ("layers",) + axes
    kv = jax.ShapeDtypeStruct(shape, dtype)
    ln = jax.ShapeDtypeStruct((n_layers,) if n_layers is not None else (),
                              jnp.int32)
    specs = {"k": kv, "v": kv, "length": ln}
    laxes = {"k": axes, "v": axes,
             "length": ("layers",) if n_layers is not None else ()}
    return specs, laxes


# ----------------------------------------------------------------------
# MLP
# ----------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, stacked: int | None = None,
              d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    lead = (stacked,) if stacked else ()
    lax_ = ("layers",) if stacked else ()
    out = {
        "w_up": ParamSpec(lead + (d, f), lax_ + ("embed", "mlp")),
        "w_down": ParamSpec(lead + (f, d), lax_ + ("mlp", "embed")),
    }
    if cfg.mlp_type == "swiglu":
        out["w_gate"] = ParamSpec(lead + (d, f), lax_ + ("embed", "mlp"))
    return out


def apply_mlp(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    if cfg.mlp_type == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        h = jax.nn.silu(gate.astype(jnp.float32)) * up.astype(jnp.float32)
    else:
        h = jax.nn.gelu(up.astype(jnp.float32))
    return jnp.einsum("bsf,fd->bsd", h.astype(x.dtype), params["w_down"])


# ----------------------------------------------------------------------
# Embeddings / head
# ----------------------------------------------------------------------

def embed_specs(cfg: ModelConfig) -> dict:
    v = cfg.padded_vocab
    out = {"tok": ParamSpec((v, cfg.d_model), ("vocab", "embed"),
                            init="embed", scale=1.0)}
    if cfg.pos == "learned":
        out["pos"] = ParamSpec((cfg.max_learned_pos, cfg.d_model),
                               (None, "embed"), init="embed", scale=0.02)
    if not cfg.tie_embeddings:
        out["head"] = ParamSpec((cfg.d_model, v), ("embed", "vocab"))
    return out


def embed_tokens(params: dict, tokens: jnp.ndarray, cfg: ModelConfig,
                 positions: jnp.ndarray) -> jnp.ndarray:
    from ..distributed.autosharding import constrain
    x = params["tok"][tokens]
    if cfg.pos == "learned":
        x = x + params["pos"][positions % cfg.max_learned_pos]
    return constrain(x.astype(cfg.dtype), ("batch", None, None))


def lm_logits(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    from ..distributed.autosharding import constrain
    x = constrain(x, ("batch", None, None))
    if cfg.tie_embeddings:
        out = jnp.einsum("bsd,vd->bsv", x, params["tok"].astype(x.dtype))
    else:
        out = jnp.einsum("bsd,dv->bsv", x, params["head"])
    if cfg.padded_vocab != cfg.vocab:
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
        out = jnp.where(pad_mask, out, -1e30)
    return constrain(out, ("batch", None, "vocab"))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  z_loss: float = 1e-4) -> jnp.ndarray:
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * lse ** 2
    return jnp.mean(loss)
