"""Encoder-decoder family (SeamlessM4T-medium backbone).

The speech frontend is a stub per the assignment: ``batch['frontend']``
carries precomputed frame embeddings (B, S_enc, frontend_dim), projected
into d_model.  Encoder = bidirectional self-attention stack; decoder =
causal self-attention + cross-attention.  Cross K/V are computed once at
prefill and cached — decode touches the encoder output only through them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import (apply_mlp, apply_norm, attention, attention_specs,
                     cross_entropy, embed_specs, embed_tokens, lm_logits,
                     make_kv_cache, mlp_specs, norm_specs)
from .param import ParamSpec, SpecTree
from .transformer import _maybe_remat, frontend_specs


def encdec_specs(cfg: ModelConfig) -> SpecTree:
    Le, Ld = cfg.n_layers, cfg.n_dec_layers
    return {
        "embed": embed_specs(cfg),
        "frontend": frontend_specs(cfg),
        "enc_blocks": {
            "attn_norm": norm_specs(cfg, Le),
            "attn": attention_specs(cfg, Le),
            "mlp_norm": norm_specs(cfg, Le),
            "mlp": mlp_specs(cfg, Le),
        },
        "dec_blocks": {
            "self_norm": norm_specs(cfg, Ld),
            "self_attn": attention_specs(cfg, Ld),
            "cross_norm": norm_specs(cfg, Ld),
            "cross_attn": attention_specs(cfg, Ld),
            "mlp_norm": norm_specs(cfg, Ld),
            "mlp": mlp_specs(cfg, Ld),
        },
        "enc_final_norm": norm_specs(cfg),
        "final_norm": norm_specs(cfg),
    }


def _encode(params, frames, cfg: ModelConfig):
    x = frames.astype(cfg.dtype) @ params["frontend"]["proj"]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, pl):
        h = apply_norm(pl["attn_norm"], x, cfg)
        a, _ = attention(pl["attn"], h, cfg, positions=positions,
                         causal=False)
        x = x + a
        h = apply_norm(pl["mlp_norm"], x, cfg)
        return x + apply_mlp(pl["mlp"], h, cfg), None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["enc_blocks"],
                        unroll=cfg.scan_unroll)
    return apply_norm(params["enc_final_norm"], x, cfg)


def _cross_kv(pl, enc_out, cfg: ModelConfig):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, pl["cross_attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, pl["cross_attn"]["wv"])
    if cfg.qkv_bias:
        k = k + pl["cross_attn"]["bk"]
        v = v + pl["cross_attn"]["bv"]
    return k, v


def _cross_apply(pl, x, ck, cv, cfg: ModelConfig):
    from .layers import _gqa_decode, _gqa_scores_full
    q = jnp.einsum("bsd,dhk->bshk", x, pl["cross_attn"]["wq"])
    if cfg.qkv_bias:
        q = q + pl["cross_attn"]["bq"]
    out = _gqa_scores_full(q, ck, cv, causal=False, q_offset=0,
                           chunk=cfg.chunk_size)
    return jnp.einsum("bshk,hkd->bsd", out, pl["cross_attn"]["wo"])


def _decoder(params, tokens, enc_out, cfg: ModelConfig, cache=None,
             decode=False, cross_kv=None):
    B, S = tokens.shape
    if decode:
        length = cache["length"][0]
        positions = jnp.broadcast_to(length, (B, 1))
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = embed_tokens(params["embed"], tokens, cfg, positions)

    if cross_kv is None:
        def kv_body(_, pl):
            return None, _cross_kv(pl, enc_out, cfg)
        _, cross_kv = jax.lax.scan(kv_body, None, params["dec_blocks"],
                                   unroll=cfg.scan_unroll)

    def body(x, xs):
        pl, cache_l, ck, cv = xs
        h = apply_norm(pl["self_norm"], x, cfg)
        a, new_cache = attention(pl["self_attn"], h, cfg,
                                 positions=positions, cache=cache_l,
                                 decode=decode)
        x = x + a
        h = apply_norm(pl["cross_norm"], x, cfg)
        x = x + _cross_apply(pl, h, ck, cv, cfg)
        h = apply_norm(pl["mlp_norm"], x, cfg)
        return x + apply_mlp(pl["mlp"], h, cfg), new_cache

    body_fn = _maybe_remat(body, cfg) if not decode else body
    x, new_cache = jax.lax.scan(body_fn, x,
                                (params["dec_blocks"], cache, *cross_kv),
                                unroll=cfg.scan_unroll)
    x = apply_norm(params["final_norm"], x, cfg)
    return lm_logits(params["embed"], x, cfg), new_cache, cross_kv


# ---- entry points ----------------------------------------------------

def loss_fn(params, batch, cfg: ModelConfig):
    enc_out = _encode(params, batch["frontend"], cfg)
    logits, _, _ = _decoder(params, batch["tokens"], enc_out, cfg)
    return cross_entropy(logits, batch["labels"])


def prefill(params, batch, cfg: ModelConfig, max_len: int):
    enc_out = _encode(params, batch["frontend"], cfg)
    B = batch["tokens"].shape[0]
    cache = make_kv_cache(cfg, B, max_len, n_layers=cfg.n_dec_layers,
                          dtype=cfg.dtype)
    logits, cache, cross_kv = _decoder(params, batch["tokens"], enc_out,
                                       cfg, cache=cache)
    return logits[:, -1:], {"self": cache, "cross": cross_kv}


def decode_step(params, batch, cache, cfg: ModelConfig):
    logits, new_self, _ = _decoder(params, batch["tokens"], None, cfg,
                                   cache=cache["self"], decode=True,
                                   cross_kv=cache["cross"])
    return logits, {"self": new_self, "cross": cache["cross"]}
