"""Parameter-spec trees: shapes, logical axes, and initializers in one place.

A model is described by a nested dict of :class:`ParamSpec`.  From the same
tree we derive:

  * abstract parameters for the dry-run (``jax.eval_shape`` — no allocation);
  * real initialized parameters for smoke tests / training;
  * `PartitionSpec`s via the logical-axis rules in ``repro.distributed``.

Logical axis names used across the zoo:

  layers   stacked layer dim (scanned; never sharded)
  embed    d_model         — FSDP axis (sharded over ('pod','data'))
  heads    attention heads — tensor-parallel ('model')
  kv_heads KV heads        — tensor-parallel if divisible, else replicated
  qkv      per-head dim    — never sharded
  mlp      FFN hidden      — tensor-parallel ('model')
  vocab    vocabulary      — tensor-parallel ('model')
  experts  MoE experts     — expert-parallel ('model')
  state    SSM state dim   — never sharded
  conv     conv kernel tap — never sharded
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | embed | scaled
    scale: float | None = None    # stddev override
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


SpecTree = dict  # nested dict[str, ParamSpec | SpecTree]


def tree_paths(tree: SpecTree, prefix: tuple[str, ...] = ()):
    for k, v in tree.items():
        if isinstance(v, ParamSpec):
            yield prefix + (k,), v
        else:
            yield from tree_paths(v, prefix + (k,))


def map_specs(tree: SpecTree, fn: Callable[[tuple, ParamSpec], Any]):
    out = {}
    for k, v in tree.items():
        if isinstance(v, ParamSpec):
            out[k] = fn((k,), v)
        else:
            out[k] = map_specs(v, lambda p, s, _k=k: fn((_k,) + p, s))
    return out


def abstract_params(tree: SpecTree) -> dict:
    """ShapeDtypeStruct tree — the dry-run's zero-allocation stand-in."""
    return map_specs(tree, lambda p, s: jax.ShapeDtypeStruct(s.shape, s.dtype))


def _init_leaf(path: tuple, spec: ParamSpec, root_key) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    seed = np.uint32(abs(hash("/".join(path))) % (2**31))
    key = jax.random.fold_in(root_key, seed)
    if spec.scale is not None:
        std = spec.scale
    elif spec.init == "embed":
        std = 1.0
    else:
        # fan-in scaled: last-but-one axis is the input dim by convention
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = fan_in ** -0.5
    return (jax.random.normal(key, spec.shape, jnp.float32) * std
            ).astype(spec.dtype)


def init_params(tree: SpecTree, key) -> dict:
    """Deterministic per-path initialization (stable across resharding)."""
    return map_specs(tree, lambda p, s: _init_leaf(p, s, key))


def count_params(tree: SpecTree) -> int:
    total = 0
    for _, s in tree_paths(tree):
        n = 1
        for d in s.shape:
            n *= d
        total += n
    return total


def axes_tree(tree: SpecTree) -> dict:
    return map_specs(tree, lambda p, s: s.axes)
