"""Decoder-LM assembly for the dense / moe / ssm / hybrid families.

One spec builder + three entry points per family:

  * ``loss_fn(params, batch, cfg)``          — training objective
  * ``prefill(params, batch, cfg, max_len)`` — build decode caches
  * ``decode_step(params, batch, cache, cfg)`` — one token for the batch

Layer stacks are *stacked on a leading L axis* and executed with
``lax.scan`` (+ rematerialization) so the lowered HLO stays compact enough
to compile 80-layer models against a 512-device mesh on this CPU container.

The hybrid (Zamba2) family interleaves a scan over Mamba2 layers with a
single *shared* attention block applied every ``cfg.attn_every`` layers —
the shared block's weights are scan-invariants, its KV cache is indexed by
application number.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import ssm
from .layers import (apply_mlp, apply_norm, attention, attention_specs,
                     cross_entropy, embed_specs, embed_tokens, kv_cache_specs,
                     lm_logits, mlp_specs, norm_specs)
from .moe import apply_moe, moe_specs
from .param import ParamSpec, SpecTree


# ----------------------------------------------------------------------
# Spec builders
# ----------------------------------------------------------------------

def frontend_specs(cfg: ModelConfig) -> dict:
    if not cfg.frontend:
        return {}
    return {"proj": ParamSpec((cfg.frontend_dim, cfg.d_model),
                              (None, "embed"))}


def lm_specs(cfg: ModelConfig) -> SpecTree:
    L = cfg.n_layers
    specs: SpecTree = {"embed": embed_specs(cfg)}
    fn = norm_specs(cfg)
    if fn:
        specs["final_norm"] = fn
    if cfg.frontend:
        specs["frontend"] = frontend_specs(cfg)

    if cfg.family in ("dense", "moe"):
        block = {"attn": attention_specs(cfg, L)}
        an = norm_specs(cfg, L)
        if an:
            block["attn_norm"] = an
            block["mlp_norm"] = norm_specs(cfg, L)
        block["mlp"] = moe_specs(cfg, L) if cfg.family == "moe" \
            else mlp_specs(cfg, L)
        specs["blocks"] = block
    elif cfg.family == "ssm":
        assert cfg.ssm_type == "rwkv6"
        block = dict(ssm.rwkv6_specs(cfg, L))
        block["tm_norm"] = norm_specs(cfg, L)
        block["cm_norm"] = norm_specs(cfg, L)
        specs["blocks"] = block
    elif cfg.family == "hybrid":
        assert cfg.ssm_type == "mamba2"
        block = dict(ssm.mamba2_specs(cfg, L))
        block["norm"] = norm_specs(cfg, L)
        specs["blocks"] = block
        shared = {"attn": attention_specs(cfg),
                  "attn_norm": norm_specs(cfg),
                  "mlp_norm": norm_specs(cfg),
                  "mlp": mlp_specs(cfg)}
        specs["shared"] = shared
    else:
        raise ValueError(cfg.family)
    return specs


def n_attn_apps(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every if cfg.attn_every else 0


# ----------------------------------------------------------------------
# Block bodies
# ----------------------------------------------------------------------

def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def _dense_block(pl, x, positions, cache_l, cfg: ModelConfig, decode: bool):
    h = apply_norm(pl.get("attn_norm", {}), x, cfg)
    a, new_cache = attention(pl["attn"], h, cfg, positions=positions,
                             cache=cache_l, decode=decode)
    x = x + a
    h = apply_norm(pl.get("mlp_norm", {}), x, cfg)
    m = apply_moe(pl["mlp"], h, cfg) if cfg.family == "moe" \
        else apply_mlp(pl["mlp"], h, cfg)
    return x + m, new_cache


def _rwkv_block(pl, x, state_l, cfg: ModelConfig, decode: bool):
    st, tm_carry, cm_carry = state_l
    h = apply_norm(pl["tm_norm"], x, cfg)
    y, (st2, tm2) = ssm.rwkv6_time_mix(pl, h, tm_carry, cfg, state=st,
                                       decode=decode)
    x = x + y
    h = apply_norm(pl["cm_norm"], x, cfg)
    y, cm2 = ssm.rwkv6_channel_mix(pl, h, cm_carry, cfg, decode=decode)
    return x + y, (st2, tm2, cm2)


def _mamba_block(pl, x, state_l, cfg: ModelConfig, decode: bool):
    st, conv = state_l
    h = apply_norm(pl["norm"], x, cfg)
    y, (st2, conv2) = ssm.mamba2_block(pl, h, cfg, state=st,
                                       conv_state=conv, decode=decode)
    return x + y, (st2, conv2)


def _shared_attn_block(ps, x, positions, cache_app, cfg: ModelConfig,
                       decode: bool):
    h = apply_norm(ps["attn_norm"], x, cfg)
    a, new_cache = attention(ps["attn"], h, cfg, positions=positions,
                             cache=cache_app, decode=decode)
    x = x + a
    h = apply_norm(ps["mlp_norm"], x, cfg)
    return x + apply_mlp(ps["mlp"], h, cfg), new_cache


# ----------------------------------------------------------------------
# Stacks
# ----------------------------------------------------------------------

def _stack_dense(params, x, positions, cache, cfg: ModelConfig,
                 decode: bool):
    def body(carry, xs):
        x = carry
        pl, cache_l = xs
        x, new_cache = _dense_block(pl, x, positions, cache_l, cfg, decode)
        return x, new_cache

    body = _maybe_remat(body, cfg) if not decode else body
    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache),
                                unroll=cfg.scan_unroll)
    return x, new_cache


def _stack_rwkv(params, x, state, cfg: ModelConfig, decode: bool):
    def body(carry, xs):
        x = carry
        pl, state_l = xs
        x, new_state = _rwkv_block(pl, x, state_l, cfg, decode)
        return x, new_state

    body = _maybe_remat(body, cfg) if not decode else body
    x, new_state = jax.lax.scan(body, x, (params["blocks"], state),
                                unroll=cfg.scan_unroll)
    return x, new_state


def _tree_split(tree, n: int, group: int):
    """Split stacked (L, ...) leaves into ((G, group, ...), (tail, ...))."""
    head = jax.tree.map(
        lambda a: a[:n * group].reshape(n, group, *a.shape[1:]), tree)
    tail = jax.tree.map(lambda a: a[n * group:], tree)
    return head, tail


def _tree_merge(head, tail, n: int, group: int):
    def m(h, t):
        flat = h.reshape(n * group, *h.shape[2:])
        return jnp.concatenate([flat, t], axis=0) if t.shape[0] else flat
    return jax.tree.map(m, head, tail)


def _stack_hybrid(params, x, positions, state, cfg: ModelConfig,
                  decode: bool):
    """Nested scans: outer over shared-attention *groups* (``attn_every``
    Mamba2 layers + one application of the shared block), then a tail scan
    over the leftover Mamba2 layers.  The shared block's weights are scan
    invariants; its KV cache is the outer scan's per-group xs."""
    every = cfg.attn_every
    G = n_attn_apps(cfg)
    mamba_state, attn_cache = state
    blocks_g, blocks_t = _tree_split(params["blocks"], G, every)
    state_g, state_t = _tree_split(mamba_state, G, every)

    def inner(carry, xs):
        x = carry
        pl, state_l = xs
        x, new_state = _mamba_block(pl, x, state_l, cfg, decode)
        return x, new_state

    def group_body(carry, xs):
        x = carry
        pg, sg, cache_g = xs
        x, sg2 = jax.lax.scan(inner, x, (pg, sg), unroll=cfg.scan_unroll)
        x, cache_g2 = _shared_attn_block(params["shared"], x, positions,
                                         cache_g, cfg, decode)
        return x, (sg2, cache_g2)

    group_fn = _maybe_remat(group_body, cfg) if not decode else group_body
    x, (state_g2, attn_cache2) = jax.lax.scan(
        group_fn, x, (blocks_g, state_g, attn_cache),
        unroll=cfg.scan_unroll)

    tail_fn = _maybe_remat(inner, cfg) if not decode else inner
    x, state_t2 = jax.lax.scan(tail_fn, x, (blocks_t, state_t),
                               unroll=cfg.scan_unroll)
    new_mamba = _tree_merge(state_g2, state_t2, G, every)
    return x, (new_mamba, attn_cache2)


# ----------------------------------------------------------------------
# Embedding of (tokens [+ frontend]) into the sequence
# ----------------------------------------------------------------------

def embed_input(params, batch, cfg: ModelConfig):
    """Returns (x, positions, n_prefix) where n_prefix is the number of
    frontend positions prepended ahead of the text tokens."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    n_prefix = 0
    xs = []
    if cfg.frontend == "vision" and "frontend" in batch:
        emb = batch["frontend"].astype(cfg.dtype) @ params["frontend"]["proj"]
        n_prefix = emb.shape[1]
        xs.append(emb)
    positions = jnp.broadcast_to(jnp.arange(S + n_prefix)[None],
                                 (B, S + n_prefix))
    tok_pos = positions[:, n_prefix:]
    xs.append(embed_tokens(params["embed"], tokens, cfg, tok_pos))
    x = jnp.concatenate(xs, axis=1) if len(xs) > 1 else xs[0]
    return x, positions, n_prefix


# ----------------------------------------------------------------------
# Entry points
# ----------------------------------------------------------------------

def forward(params, batch, cfg: ModelConfig, cache=None, decode=False):
    if decode:
        length = _cache_length(cache, cfg)
        B = batch["tokens"].shape[0]
        positions = jnp.broadcast_to(length, (B, 1))
        x = embed_tokens(params["embed"], batch["tokens"], cfg, positions)
    else:
        x, positions, _ = embed_input(params, batch, cfg)

    if cfg.family in ("dense", "moe"):
        x, cache = _stack_dense(params, x, positions, cache, cfg, decode)
    elif cfg.family == "ssm":
        state, counter = cache
        x, state = _stack_rwkv(params, x, state, cfg, decode)
        cache = (state, counter + x.shape[1])
    else:
        x, cache = _stack_hybrid(params, x, positions, cache, cfg, decode)

    if "final_norm" in params:
        x = apply_norm(params["final_norm"], x, cfg)
    logits = lm_logits(params["embed"], x, cfg)
    return logits, cache


def loss_fn(params, batch, cfg: ModelConfig):
    cache = empty_cache(params, batch, cfg, train=True)
    logits, _ = forward(params, batch, cfg, cache=cache)
    n_prefix = logits.shape[1] - batch["labels"].shape[1]
    if n_prefix:
        logits = logits[:, n_prefix:]
    return cross_entropy(logits, batch["labels"])


def prefill(params, batch, cfg: ModelConfig, max_len: int):
    cache = empty_cache(params, batch, cfg, train=False, max_len=max_len)
    logits, cache = forward(params, batch, cfg, cache=cache)
    return logits[:, -1:], cache


def decode_step(params, batch, cache, cfg: ModelConfig):
    logits, cache = forward(params, batch, cfg, cache=cache, decode=True)
    return logits, cache


# ----------------------------------------------------------------------
# Caches / recurrent state
# ----------------------------------------------------------------------

def _cache_length(cache, cfg: ModelConfig):
    if cfg.family in ("dense", "moe"):
        return cache["length"][0]
    if cfg.family == "hybrid":
        return cache[1]["length"][0]
    return cache[1]  # rwkv: explicit token counter


def empty_cache(params, batch, cfg: ModelConfig, *, train: bool,
                max_len: int = 0):
    """Concrete zero cache (smoke tests / real decode).  For dense training
    the per-layer cache is None-like (no KV retention)."""
    B = batch["tokens"].shape[0]
    L = cfg.n_layers
    if cfg.family in ("dense", "moe"):
        if train:
            return None
        from .layers import make_kv_cache
        return make_kv_cache(cfg, B, max_len, n_layers=L, dtype=cfg.dtype)
    if cfg.family == "ssm":
        H, K = cfg.n_heads, cfg.d_model // cfg.n_heads
        st = jnp.zeros((L, B, H, K, K), jnp.float32)
        carry = jnp.zeros((L, B, 1, cfg.d_model), cfg.dtype)
        return ((st, carry, carry), jnp.asarray(0, jnp.int32))
    # hybrid
    H, N = cfg.ssm_heads, cfg.ssm_state
    P = cfg.d_model * cfg.ssm_expand // H
    di = cfg.d_model * cfg.ssm_expand
    st = jnp.zeros((L, B, H, N, P), jnp.float32)
    conv = jnp.zeros((L, B, cfg.conv_width - 1, di + 2 * N), cfg.dtype)
    mamba = (st, conv)
    if train:
        return (mamba, None)
    from .layers import make_kv_cache
    apps = max(1, n_attn_apps(cfg))
    attn = make_kv_cache(cfg, B, max(max_len, 1), n_layers=apps,
                         dtype=cfg.dtype)
    return (mamba, attn)
