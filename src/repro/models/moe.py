"""Mixture-of-Experts layer: top-k routing with per-row capacity, scatter/
gather dispatch (O(T·D) memory — no dense (T,E,C) one-hots), expert-parallel
weight stacking.

In MAESTRO terms this layer is a spatial map of the `E` dim across the
`model` mesh axis (expert parallelism); the scatter/gather turn into
all-to-all collectives under the SPMD partitioner — exactly the taxonomy's
"spatial distribution of a coupled dim" case.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .param import ParamSpec


def moe_specs(cfg: ModelConfig, stacked: int | None = None) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    lead = (stacked,) if stacked else ()
    lax_ = ("layers",) if stacked else ()
    out = {
        "router": ParamSpec(lead + (d, e), lax_ + ("embed", None),
                            scale=d ** -0.5),
        "w_up": ParamSpec(lead + (e, d, f), lax_ + ("experts", "embed", "mlp")),
        "w_gate": ParamSpec(lead + (e, d, f), lax_ + ("experts", "embed", "mlp")),
        "w_down": ParamSpec(lead + (e, f, d), lax_ + ("experts", "mlp", "embed")),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        out["shared_up"] = ParamSpec(lead + (d, fs), lax_ + ("embed", "mlp"))
        out["shared_gate"] = ParamSpec(lead + (d, fs), lax_ + ("embed", "mlp"))
        out["shared_down"] = ParamSpec(lead + (fs, d), lax_ + ("mlp", "embed"))
    return out


def _expert_ffn(params, xe, cfg: ModelConfig):
    """xe: (B, E, C, D) -> (B, E, C, D), experts along axis 1."""
    up = jnp.einsum("becd,edf->becf", xe, params["w_up"])
    gate = jnp.einsum("becd,edf->becf", xe, params["w_gate"])
    h = jax.nn.silu(gate.astype(jnp.float32)) * up.astype(jnp.float32)
    return jnp.einsum("becf,efd->becd", h.astype(xe.dtype),
                      params["w_down"])


def apply_moe(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """x: (B, S, D).  Per-row (per-batch-element) capacity so routing state
    stays local to the data shards."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    cap = max(1, int(S * k / E * cfg.capacity_factor))

    logits = jnp.einsum("bsd,de->bse", x, params["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, k)            # (B, S, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert, per batch row
    # (int16 routing state: cap and slot counts are < 2^15)
    flat_e = idx.reshape(B, S * k)                   # (B, T')
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int16)
    pos = (jnp.cumsum(onehot, axis=1) - 1).astype(jnp.int32)
    pos = jnp.take_along_axis(pos, flat_e[..., None], axis=-1)[..., 0]
    keep = pos < cap
    # scatter target: index into (E*cap + 1) slots, overflow -> sentinel
    slot = jnp.where(keep, flat_e * cap + pos, E * cap)   # (B, T')
    slot = slot.reshape(B, S, k)

    # one fused scatter of all (token, slot) pairs — a per-slot loop was
    # tried and REFUTED in §Perf-B (k read-modify-write passes over the
    # expert buffer cost more traffic than one repeated-activation pass)
    slot_flat = slot.reshape(B, S * k)
    x_slots = jnp.repeat(x, k, axis=1)               # (B, S*k, D)
    buf = jnp.zeros((B, E * cap + 1, D), x.dtype)
    buf = jax.vmap(lambda b, s_, xs: b.at[s_].add(xs))(buf, slot_flat,
                                                       x_slots)
    xe = buf[:, :E * cap].reshape(B, E, cap, D)

    ye = _expert_ffn(params, xe, cfg)                # (B, E, cap, D)
    ye_flat = jnp.concatenate(
        [ye.reshape(B, E * cap, D), jnp.zeros((B, 1, D), ye.dtype)], axis=1)
    y_slots = jax.vmap(lambda yf, s_: yf[s_])(ye_flat, slot_flat)
    w = (gates * keep.reshape(B, S, k)).astype(jnp.float32)
    y = jnp.sum(y_slots.astype(jnp.float32).reshape(B, S, k, D)
                * w[..., None], axis=2)

    if cfg.n_shared_experts:
        up = jnp.einsum("bsd,df->bsf", x, params["shared_up"])
        gate = jnp.einsum("bsd,df->bsf", x, params["shared_gate"])
        h = jax.nn.silu(gate.astype(jnp.float32)) * up.astype(jnp.float32)
        y = y + jnp.einsum("bsf,fd->bsd", h.astype(x.dtype),
                           params["shared_down"]).astype(jnp.float32)
    return y.astype(x.dtype)


def aux_load_balance_loss(logits: jnp.ndarray, idx: jnp.ndarray,
                          n_experts: int) -> jnp.ndarray:
    """Switch-style load-balance auxiliary loss (returned by the train
    path; weight configured by the trainer)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    me = jnp.mean(probs.reshape(-1, n_experts), axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(idx[..., 0].reshape(-1), n_experts,
                       dtype=jnp.float32), axis=0)
    return n_experts * jnp.sum(me * ce)
