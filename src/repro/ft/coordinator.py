"""Fault tolerance: checkpoint/restart coordination and straggler
mitigation for the training loop.

``FaultTolerantLoop`` wraps a step function with:

  * periodic (async) checkpoints via :class:`Checkpointer`;
  * restart-on-failure: any exception from a step (a real XLA error, or an
    injected fault in tests) triggers restore-from-last-good and replay —
    the data pipeline is stateless in the step index, so replayed batches
    are bit-identical;
  * a straggler watchdog: per-step wall times feed
    :class:`repro.resilience.StragglerWatchdog` (the EWMA detector that
    also watches sweep device chunks); steps slower than ``threshold ×``
    the EWMA are flagged.  On a real pod the hook would drain and
    re-slice the mesh around the slow host (elastic restore onto the
    surviving device set — checkpoint/checkpointer.py already reshards);
    here the hook records the event and, if an ``on_straggler`` callback
    is provided, defers the policy to it.

MAESTRO connection: restart cost is an availability-vs-throughput design
point exactly like the paper's DSE trade-offs — the knobs (checkpoint
period vs restart replay length) are exposed so the examples can sweep
them.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from ..checkpoint.checkpointer import Checkpointer
from ..resilience import StragglerWatchdog


@dataclasses.dataclass
class FTConfig:
    checkpoint_every: int = 50
    keep: int = 3
    async_save: bool = True
    max_restarts: int = 3
    straggler_threshold: float = 3.0
    ewma_alpha: float = 0.2


@dataclasses.dataclass
class StepEvent:
    step: int
    wall_s: float
    straggler: bool
    restarted: bool = False


class FaultTolerantLoop:
    def __init__(self, step_fn: Callable, checkpointer: Checkpointer,
                 cfg: FTConfig | None = None,
                 on_straggler: Callable[[StepEvent], None] | None = None,
                 fault_injector: Callable[[int], None] | None = None):
        self.step_fn = step_fn
        self.ckpt = checkpointer
        self.cfg = cfg or FTConfig()
        self.on_straggler = on_straggler
        self.fault_injector = fault_injector
        self.events: list[StepEvent] = []
        self.restarts = 0
        # own detector instance: training-step walls must not share a
        # baseline with the sweep chunk loops' CHUNK_WATCHDOG
        self._watchdog = StragglerWatchdog(
            threshold=self.cfg.straggler_threshold,
            alpha=self.cfg.ewma_alpha)

    # ------------------------------------------------------------------
    def run(self, state: Any, batch_fn: Callable[[int], Any],
            start_step: int, num_steps: int):
        """Run ``num_steps`` from ``start_step``; returns (state, step).
        ``state`` is the (params, opt_state, ...) tuple the step_fn maps
        over; ``batch_fn(step)`` materializes the deterministic batch."""
        step = start_step
        end = start_step + num_steps
        while step < end:
            try:
                if self.fault_injector is not None:
                    self.fault_injector(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch_fn(step))
                wall = time.perf_counter() - t0
                self._observe(step, wall)
                step += 1
                if step % self.cfg.checkpoint_every == 0:
                    self.ckpt.save(step, state,
                                   extra={"metrics": _to_float(metrics)},
                                   async_save=self.cfg.async_save)
            except Exception:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                state, step = self._restore(state)
                self.events.append(StepEvent(step, 0.0, False,
                                             restarted=True))
        self.ckpt.wait()
        return state, step

    # ------------------------------------------------------------------
    def _restore(self, skeleton: Any):
        last = self.ckpt.latest_step()
        if last is None:
            return skeleton, 0   # cold restart from step 0
        state, manifest = self.ckpt.restore(skeleton)
        return state, manifest["step"]

    def _observe(self, step: int, wall: float) -> None:
        slow = self._watchdog.observe(wall, step=step)
        ev = StepEvent(step, wall, slow)
        self.events.append(ev)
        if slow and self.on_straggler is not None:
            self.on_straggler(ev)

    @property
    def straggler_steps(self) -> list[int]:
        return [e.step for e in self.events if e.straggler]


def _to_float(tree):
    import jax
    return jax.tree.map(lambda x: float(x), tree)
