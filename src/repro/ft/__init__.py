from .coordinator import FaultTolerantLoop, FTConfig, StepEvent

__all__ = ["FaultTolerantLoop", "FTConfig", "StepEvent"]
