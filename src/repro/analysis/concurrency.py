"""AST-based concurrency linter for the threaded modules of ``repro``.

The serving/observability stack (PRs 6–9) made a handful of structures
thread-shared and load-bearing: the coalescer buffer, the admission
EWMA, the metrics registry, the tracer event list, the flight-recorder
ring, and the on-disk result cache's gauges.  This linter encodes the
locking discipline those modules promise and checks it statically:

* ``CONC-UNLOCKED`` — inside a registered threaded module, any mutation
  of ``self.<attr>`` (assignment, augmented assignment, subscript store,
  ``del``, or a mutating container-method call) outside a ``with
  self.<lock>``/``with <module lock>`` block, in a class that owns a
  ``threading.Lock``/``RLock``/``Condition``.  ``__init__``/``__new__``
  are construction-time and exempt; classes listed in the module policy
  as *unshared* (per-call objects, or helpers only ever touched under
  an owner's lock) are exempt by annotation.
* ``CONC-GLOBAL`` — a function in a threaded module rebinding a module
  global (single-writer toggles must be waived explicitly).
* ``CONC-CONTEXTVAR`` — repo-wide: a function calls ``.set()`` on a
  module-level ``ContextVar`` without ever calling ``.reset()`` on the
  same var (leaks request/phase context across asyncio tasks reusing a
  thread).
* ``CONC-THREADLOCAL`` — repo-wide: ``threading.local()`` constructed
  inside a function body (fresh storage per *call*, which defeats the
  point; build it at module/instance scope).

The registry below is the module annotation surface the ISSUE asks for:
adding a module to ``THREADED`` turns the locking rules on for it.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Iterable

from .findings import Finding

# Container methods that mutate their receiver in place.
_MUTATORS = frozenset({
    "append", "appendleft", "extend", "extendleft", "insert", "remove",
    "pop", "popleft", "popitem", "clear", "update", "add", "discard",
    "setdefault", "sort", "reverse", "rotate",
})

_LOCK_FACTORIES = frozenset({"Lock", "RLock", "Condition", "Semaphore",
                             "BoundedSemaphore"})


@dataclasses.dataclass(frozen=True)
class ModulePolicy:
    """Per-module annotation: which classes are exempt from the
    shared-mutation rule and why (per-call objects, or helpers that are
    only ever touched while an owner holds its lock)."""
    unshared: dict[str, str] = dataclasses.field(default_factory=dict)


# The threaded-module registry (relative to ``src/repro/``).
THREADED: dict[str, ModulePolicy] = {
    "serve/coalescer.py": ModulePolicy(unshared={
        "_Pending": "request envelope: built by one handler task, "
                    "resolved once by the flush worker",
    }),
    "serve/admission.py": ModulePolicy(),
    "obs/metrics.py": ModulePolicy(unshared={
        "_Hist": "mutated only by Metrics methods holding Metrics._lock",
        "_BucketHist": "mutated only under Metrics._lock",
    }),
    "obs/trace.py": ModulePolicy(unshared={
        "_Span": "per-call context manager, never shared across threads",
        "_NullSpan": "stateless fast-path singleton",
    }),
    "obs/flightrec.py": ModulePolicy(),
    "obs/context.py": ModulePolicy(),
    "mapspace/cache.py": ModulePolicy(),
}


def _is_threading_call(node: ast.AST, names: Iterable[str]) -> bool:
    """``threading.X(...)`` or bare ``X(...)`` for X in names."""
    if not isinstance(node, ast.Call):
        return False
    fn = node.func
    if isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name) \
            and fn.value.id == "threading" and fn.attr in names:
        return True
    return isinstance(fn, ast.Name) and fn.id in names


def _self_attr(node: ast.AST) -> str | None:
    """``self.x`` -> ``"x"``."""
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _mutation_targets(stmt: ast.stmt) -> list[tuple[str, int]]:
    """Shared-state mutations in one statement: (self attr, lineno).

    Covers ``self.x = ...``, ``self.x += ...``, ``self.x[i] = ...``,
    ``del self.x`` / ``del self.x[i]`` and ``self.x.append(...)``-style
    mutating calls."""
    out: list[tuple[str, int]] = []

    def base_attr(t: ast.AST) -> str | None:
        while isinstance(t, ast.Subscript):
            t = t.value
        return _self_attr(t)

    if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
        targets = stmt.targets if isinstance(stmt, ast.Assign) \
            else [stmt.target]
        for t in targets:
            if isinstance(t, ast.Tuple):
                elts: list[ast.AST] = list(t.elts)
            else:
                elts = [t]
            for e in elts:
                a = base_attr(e)
                if a is not None:
                    out.append((a, stmt.lineno))
    elif isinstance(stmt, ast.Delete):
        for t in stmt.targets:
            a = base_attr(t)
            if a is not None:
                out.append((a, stmt.lineno))
    elif isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
        fn = stmt.value.func
        if isinstance(fn, ast.Attribute) and fn.attr in _MUTATORS:
            a = base_attr(fn.value)
            if a is not None:
                out.append((a, stmt.lineno))
    return out


def _with_locks(stmt: ast.With, lock_attrs: set[str],
                module_locks: set[str]) -> bool:
    """Does this ``with`` acquire one of the known locks?"""
    for item in stmt.items:
        e = item.context_expr
        a = _self_attr(e)
        if a is not None and a in lock_attrs:
            return True
        if isinstance(e, ast.Name) and e.id in module_locks:
            return True
        # ``with self._cv: ...`` vs ``with self._lock_for(x): ...`` —
        # a call on a lock attr (e.g. Condition.wait_for wrappers) does
        # not acquire; only the bare attr/name counts.
    return False


class _FuncChecker:
    """Walks one function body tracking lexical lock scope."""

    def __init__(self, lock_attrs: set[str], module_locks: set[str],
                 skip_attrs: set[str]) -> None:
        self.lock_attrs = lock_attrs
        self.module_locks = module_locks
        self.skip_attrs = skip_attrs
        self.unlocked: list[tuple[str, int]] = []

    def walk(self, body: list[ast.stmt], locked: bool) -> None:
        for stmt in body:
            if isinstance(stmt, ast.With):
                inner = locked or _with_locks(stmt, self.lock_attrs,
                                              self.module_locks)
                self.walk(stmt.body, inner)
                continue
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # nested function: conservatively checked in the outer
                # lock scope (closures in these modules run inline)
                self.walk(stmt.body, locked)
                continue
            if not locked:
                for attr, line in _mutation_targets(stmt):
                    if attr not in self.lock_attrs \
                            and attr not in self.skip_attrs:
                        self.unlocked.append((attr, line))
            # recurse into compound statements (if/for/try/while bodies)
            for field in ("body", "orelse", "finalbody", "handlers"):
                sub = getattr(stmt, field, None)
                if not sub:
                    continue
                if field == "handlers":
                    for h in sub:
                        self.walk(h.body, locked)
                else:
                    self.walk(sub, locked)


def _class_lock_attrs(cls: ast.ClassDef) -> set[str]:
    """Attrs assigned a threading lock/condition in ``__init__``."""
    out: set[str] = set()
    for node in cls.body:
        if isinstance(node, ast.FunctionDef) and node.name == "__init__":
            for stmt in ast.walk(node):
                if isinstance(stmt, ast.Assign) and \
                        _is_threading_call(stmt.value, _LOCK_FACTORIES):
                    for t in stmt.targets:
                        a = _self_attr(t)
                        if a is not None:
                            out.add(a)
    return out


def _module_locks(tree: ast.Module) -> set[str]:
    out: set[str] = set()
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) and \
                _is_threading_call(stmt.value, _LOCK_FACTORIES):
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
    return out


def _module_contextvars(tree: ast.Module) -> set[str]:
    out: set[str] = set()
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) and \
                isinstance(stmt.value, ast.Call):
            fn = stmt.value.func
            name = fn.attr if isinstance(fn, ast.Attribute) else \
                fn.id if isinstance(fn, ast.Name) else None
            if name == "ContextVar":
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        out.add(t.id)
    return out


def _func_qualnames(tree: ast.Module):
    """Yield (qualname, node) for every module/class-level function."""
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node.name, node
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)):
                    yield f"{node.name}.{sub.name}", sub


# ----------------------------------------------------------------------
# Per-source linting
# ----------------------------------------------------------------------

def lint_source(src: str, rel: str,
                policy: ModulePolicy | None = None) -> list[Finding]:
    """Lint one module's source.  With a ``policy`` (a registered
    threaded module) the locking rules apply; the contextvar and
    threading.local rules apply regardless."""
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Finding(code="CONC-GLOBAL", site=rel, severity="error",
                        analyzer="concurrency",
                        message=f"unparseable module: {e}")]
    findings: list[Finding] = []
    module_locks = _module_locks(tree)

    if policy is not None:
        findings += _lint_locking(tree, rel, policy, module_locks)

    findings += _lint_contextvars(tree, rel)
    findings += _lint_threadlocal(tree, rel)
    return findings


def _lint_locking(tree: ast.Module, rel: str, policy: ModulePolicy,
                  module_locks: set[str]) -> list[Finding]:
    findings: list[Finding] = []
    # classes
    for node in tree.body:
        if not isinstance(node, ast.ClassDef):
            continue
        if node.name in policy.unshared:
            continue
        lock_attrs = _class_lock_attrs(node)
        for fn in node.body:
            if not isinstance(fn, (ast.FunctionDef,
                                   ast.AsyncFunctionDef)):
                continue
            if fn.name in ("__init__", "__new__"):
                continue
            if any(isinstance(d, ast.Name) and d.id == "staticmethod"
                   for d in fn.decorator_list):
                continue
            chk = _FuncChecker(lock_attrs, module_locks, set())
            chk.walk(fn.body, locked=False)
            for attr, line in chk.unlocked:
                site = f"{rel}::{node.name}.{fn.name}"
                lock = "/".join(sorted(lock_attrs)) or "<no lock owned>"
                findings.append(Finding(
                    code="CONC-UNLOCKED", site=site,
                    analyzer="concurrency", where=f"{rel}:{line}",
                    message=f"self.{attr} mutated outside "
                            f"with self.{lock} in threaded module"))
    # module-global rebinding from functions
    for qual, fn in _func_qualnames(tree):
        declared: set[str] = set()
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Global):
                declared.update(stmt.names)
        if not declared:
            continue
        chk_lines: list[tuple[str, int]] = []
        for stmt in ast.walk(fn):
            if isinstance(stmt, (ast.Assign, ast.AugAssign)):
                targets = stmt.targets if isinstance(stmt, ast.Assign) \
                    else [stmt.target]
                for t in targets:
                    if isinstance(t, ast.Name) and t.id in declared:
                        chk_lines.append((t.id, stmt.lineno))
        for gname, line in chk_lines:
            if gname in module_locks:
                continue
            findings.append(Finding(
                code="CONC-GLOBAL", site=f"{rel}::{qual}",
                analyzer="concurrency", where=f"{rel}:{line}",
                message=f"rebinds module global {gname} from a "
                        f"function in a threaded module"))
    return findings


def _lint_contextvars(tree: ast.Module, rel: str) -> list[Finding]:
    cvars = _module_contextvars(tree)
    if not cvars:
        return []
    findings: list[Finding] = []
    for qual, fn in _func_qualnames(tree):
        sets: dict[str, int] = {}
        resets: set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    isinstance(node.func.value, ast.Name) and \
                    node.func.value.id in cvars:
                if node.func.attr == "set":
                    sets.setdefault(node.func.value.id, node.lineno)
                elif node.func.attr == "reset":
                    resets.add(node.func.value.id)
        for var, line in sets.items():
            if var not in resets:
                findings.append(Finding(
                    code="CONC-CONTEXTVAR", site=f"{rel}::{qual}",
                    analyzer="concurrency", where=f"{rel}:{line}",
                    message=f"{var}.set() without {var}.reset() — "
                            f"context leaks across tasks sharing the "
                            f"thread"))
    return findings


def _lint_threadlocal(tree: ast.Module, rel: str) -> list[Finding]:
    findings: list[Finding] = []
    for qual, fn in _func_qualnames(tree):
        if fn.name == "__init__":
            continue              # instance-scope storage is fine
        for node in ast.walk(fn):
            if _is_threading_call(node, {"local"}):
                findings.append(Finding(
                    code="CONC-THREADLOCAL", site=f"{rel}::{qual}",
                    analyzer="concurrency",
                    where=f"{rel}:{node.lineno}",
                    message="threading.local() inside a function body "
                            "creates fresh storage per call"))
    return findings


# ----------------------------------------------------------------------
# Tree driver
# ----------------------------------------------------------------------

def _src_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint_tree(root: str | None = None) -> list[Finding]:
    """Lint all of ``src/repro/``: locking rules on the registered
    threaded modules, contextvar/threading.local rules everywhere."""
    root = root or _src_root()
    findings: list[Finding] = []
    for dirpath, _dirnames, filenames in os.walk(root):
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path, encoding="utf-8") as f:
                src = f.read()
            findings += lint_source(src, rel, THREADED.get(rel))
    return findings
