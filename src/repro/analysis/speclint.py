"""Static legality analysis of dataflow programs and Query specs.

MAESTRO's core claim is that a directive program can be *analyzed* —
legality, reuse, cost — without running anything.  This linter applies
the cheap half of that claim at the system boundaries: Table-3-style
directive programs and declarative ``Query`` specs are checked for
static legality BEFORE any XLA compile, so an illegal spec is a
one-line structured answer instead of a burned flush slot (the serving
tier runs :func:`lint_query` pre-admission at ``POST /query``).

Checks (all numpy/stdlib — importing this module never pulls jax):

* ``SPEC-PARSE``/``SPEC-ILLEGAL`` — structural validation + size/offset
  legality via ``core.directives`` (``validate``/``is_legal``);
* ``SPEC-TILE`` — a *steady* temporal tile (offset == size, i.e. a
  disjoint tiling, not a sliding window) that does not divide its dim's
  extent produces edge phases and knocks the program off the
  divisor-exact universal fast path;
* ``SPEC-CLUSTER`` — empty inner cluster level, or a cluster size
  exceeding the PE array when the hardware point is known;
* ``SPEC-SPATIAL`` — multiple SpatialMaps at one level must be
  *aligned* (equal sizes — Table 3 YR-P's Y/R diagonal);
* ``SPEC-DIMS``/``SPEC-SPACE`` — the query's searched dims must induce
  a non-empty legal mapping space for every resolved layer;
* ``SPEC-BUDGET`` — the analytic working-set LOWER bound of the
  smallest mapping in the space (``mapspace.space.buffer_estimate_kb``
  at minimum tiles) already exceeds the configured L1/L2 prune budget:
  the search is statically infeasible and every candidate would be
  pruned.

``check_query`` surfaces error findings through the PR-7 ``SpecError``
path, so CLI/API callers get the familiar one-line exit-2 behaviour
with the findings attached as structured detail.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Iterable, Mapping

from ..core import dataflows as _df
from ..core import directives as _d
from ..core.directives import (Cluster, Dataflow, DataflowError,
                               SpatialMap, TemporalMap)
from ..core.tensor_analysis import LayerOp, conv1d_outputs, conv2d
from .findings import Finding

if TYPE_CHECKING:                     # annotation-only: no api import cost
    from ..api.spec import Query


# ----------------------------------------------------------------------
# Dataflow programs
# ----------------------------------------------------------------------

def _levels(df: Dataflow) -> list[tuple[Cluster | None, list]]:
    """Split a directive program at Cluster boundaries:
    [(cluster_or_None, [maps...]), ...] outermost first."""
    out: list[tuple[Cluster | None, list]] = [(None, [])]
    for d in df.directives:
        if isinstance(d, Cluster):
            out.append((d, []))
        else:
            out[-1][1].append(d)
    return out


def lint_dataflow(df: Dataflow, op: LayerOp | Mapping[str, int], *,
                  num_pes: int | None = None,
                  site: str | None = None) -> list[Finding]:
    """Static legality findings for one directive program against one
    layer's dims.  Empty list == legal (and fast-path friendly)."""
    dims = dict(op if isinstance(op, Mapping) else op.dims)
    site = site or f"dataflow::{df.name}"
    findings: list[Finding] = []

    try:
        _d.validate(df.directives)
        ext = _d.extended_dims(df, dims)
        res = _d.resolve(df, ext)
    except DataflowError as e:
        return [Finding(code="SPEC-PARSE", site=site, analyzer="speclint",
                        message=str(e))]

    if not _d.is_legal(res, dims):
        findings.append(Finding(
            code="SPEC-ILLEGAL", site=site, analyzer="speclint",
            message="a directive size/offset is non-positive or larger "
                    "than its (extended) dim extent"))
    # the RAW program, pre-clamp: a static span exceeding the dim's
    # extent is the paper's asterisk case — resolve() silently clamps
    # it to fully-unrolled, which is rarely what the author meant
    for m in df.directives:
        if isinstance(m, Cluster):
            continue
        extent = ext.get(m.dim, 1)
        for what, v in (("size", m.size), ("offset", m.offset)):
            if _d.is_static_size(v) and v != _d.FULL and v > extent:
                findings.append(Finding(
                    code="SPEC-ILLEGAL", site=site, analyzer="speclint",
                    severity="warn",
                    message=f"{type(m).__name__} {what} {v} exceeds "
                            f"dim {m.dim} extent {extent}: resolve() "
                            f"clamps it to a fully-unrolled map"))

    for cl, maps in _levels(res):
        if cl is not None:
            csize = cl.size
            if not maps:
                findings.append(Finding(
                    code="SPEC-CLUSTER", site=site, analyzer="speclint",
                    message=f"Cluster({csize}) with an empty inner "
                            f"level — nothing is mapped inside the "
                            f"cluster"))
            if num_pes is not None and _d.is_static_size(csize) \
                    and csize > num_pes:
                findings.append(Finding(
                    code="SPEC-CLUSTER", site=site, analyzer="speclint",
                    message=f"Cluster({csize}) exceeds the PE array "
                            f"({num_pes} PEs): at most one degenerate "
                            f"cluster fits"))
        spatial = [m for m in maps if isinstance(m, SpatialMap)]
        if len(spatial) > 1:
            sizes = {m.size for m in spatial
                     if _d.is_static_size(m.size)}
            if len(sizes) > 1:
                findings.append(Finding(
                    code="SPEC-SPATIAL", site=site, analyzer="speclint",
                    message=f"{len(spatial)} SpatialMaps at one level "
                            f"with unequal sizes {sorted(sizes)} — "
                            f"aligned distribution needs equal spans"))
        for m in maps:
            if not isinstance(m, TemporalMap):
                continue          # spatial edges are modelled exactly
            if not (_d.is_static_size(m.size)
                    and _d.is_static_size(m.offset)):
                continue
            if m.size != m.offset:
                continue          # sliding window: recompute by design
            extent = ext.get(m.dim, 1)
            if m.size < extent and extent % m.size:
                findings.append(Finding(
                    code="SPEC-TILE", site=site, analyzer="speclint",
                    severity="warn",
                    message=f"TemporalMap({m.size},{m.offset}) {m.dim} "
                            f"does not divide extent {extent}: edge "
                            f"phases put the program on the slow "
                            f"(grouped) path"))
    return findings


def lint_text(text: str, op: LayerOp | Mapping[str, int], *,
              num_pes: int | None = None,
              site: str = "dataflow::<text>") -> list[Finding]:
    """Lint a user-authored textual directive program (the paper's
    syntax, via ``directives.parse``).  A syntax or structural error is
    a ``SPEC-PARSE`` finding instead of an exception — this is the
    front door for the ROADMAP user-authored-dataflow item."""
    try:
        df = _d.parse(text)
    except DataflowError as e:
        return [Finding(code="SPEC-PARSE", site=site, analyzer="speclint",
                        message=str(e))]
    return lint_dataflow(df, op, num_pes=num_pes, site=site)


# ----------------------------------------------------------------------
# Shipped corpus: the paper's programs must stay clean
# ----------------------------------------------------------------------

def _reference_ops() -> dict[str, LayerOp]:
    """Reference layers the shipped corpus is linted against: a VGG-ish
    conv for the Table-3 styles, the paper's Fig. 4/5 1-D conv for the
    pedagogical programs."""
    return {
        "conv": conv2d("lint-conv", k=64, c=64, y=28, x=28, r=3, s=3),
        "conv1d": conv1d_outputs("lint-conv1d", x_out=18, s=3),
    }


def lint_corpus() -> list[Finding]:
    """Lint every shipped dataflow program (Table 3, Fig. 4/5, the
    6-PE row-stationary example) against its reference layer.  The
    zero-findings CI gate runs this: the paper's own programs must
    never trip the linter."""
    ops = _reference_ops()
    findings: list[Finding] = []
    for name in _df.TABLE3:
        df = _df.table3_for_layer(name, ops["conv"])
        findings += lint_dataflow(df, ops["conv"],
                                  site=f"core/dataflows.py::{name}")
    for key, df in _df.FIG5.items():
        findings += lint_dataflow(df, ops["conv1d"],
                                  site=f"core/dataflows.py::FIG5_{key}")
    findings += lint_dataflow(_df.FIG4, ops["conv1d"],
                              site="core/dataflows.py::FIG4")
    findings += lint_dataflow(_df.ROW_STATIONARY_6PE, ops["conv"],
                              site="core/dataflows.py::"
                                   "ROW_STATIONARY_6PE")
    return findings


# ----------------------------------------------------------------------
# Query specs (the serving tier's pre-admission lint)
# ----------------------------------------------------------------------

def _min_point(space) -> tuple:
    """The gene point with the smallest working set: minimum tile on
    every axis, no cluster (tile_candidates sorts ascending)."""
    return (0, 0, 0) + (0,) * len(space.axes)


def _lint_layer(op: LayerOp, q: "Query", site: str) -> list[Finding]:
    # numpy-only imports: build_space/buffer_estimate_kb never touch jax
    from ..mapspace.space import (MapSpaceError, build_space,
                                  buffer_estimate_kb)
    spec = q.search
    findings: list[Finding] = []
    if spec.dims:
        bad = [d for d in spec.dims if d not in op.dims]
        if bad:
            findings.append(Finding(
                code="SPEC-DIMS", site=site, analyzer="speclint",
                message=f"searched dims {bad} are not dims of "
                        f"{op.name} (has {sorted(op.dims)})"))
            return findings
    try:
        space = build_space(op, dims=spec.dims, cluster=spec.cluster)
    except MapSpaceError as e:
        findings.append(Finding(
            code="SPEC-SPACE", site=site, analyzer="speclint",
            message=str(e)))
        return findings

    num_pes = q.hardware.num_pes
    for copt in space.cluster_options:
        if copt is not None and copt.size > num_pes:
            findings.append(Finding(
                code="SPEC-CLUSTER", site=site, analyzer="speclint",
                severity="warn",
                message=f"cluster option size {copt.size} > "
                        f"{num_pes} PEs: clamps to one degenerate "
                        f"cluster at evaluation time"))

    l1_budget = spec.l1_prune_kb
    l2_budget = spec.l2_prune_kb
    if l1_budget is not None or l2_budget is not None:
        e1, e2 = buffer_estimate_kb(op, space, _min_point(space))
        if l1_budget is not None and e1 > l1_budget:
            findings.append(Finding(
                code="SPEC-BUDGET", site=site, analyzer="speclint",
                message=f"l1_prune_kb={l1_budget}: even the smallest "
                        f"mapping needs >= {e1:.1f} KB of L1 — every "
                        f"candidate would be pruned"))
        if l2_budget is not None and e2 > l2_budget:
            findings.append(Finding(
                code="SPEC-BUDGET", site=site, analyzer="speclint",
                message=f"l2_prune_kb={l2_budget}: even the smallest "
                        f"mapping needs >= {e2:.1f} KB of L2 — every "
                        f"candidate would be pruned"))
    return findings


def lint_query(q: "Query") -> list[Finding]:
    """Static findings for one declarative query: searched-dim
    validity, space constructibility, cluster-vs-PE sanity, and the
    analytic buffer-budget feasibility bound — per resolved layer, all
    before any compile.  ``Query.__post_init__`` has already enforced
    field-level validity; this is the cross-field/workload layer."""
    site_base = f"query::{q.tag or q.workload.describe().get('model') or 'layer'}"
    findings: list[Finding] = []
    try:
        ops = q.workload.resolve()
    except Exception:
        return findings            # resolution errors surface as SpecError
    seen: set[tuple] = set()
    for op in ops:
        shape = (op.op_type, tuple(sorted(op.dims.items())))
        if shape in seen:
            continue               # one lint per unique layer shape
        seen.add(shape)
        findings += _lint_layer(op, q, f"{site_base}::{op.name}")
    return findings


def check_query(q: "Query") -> None:
    """Raise a one-line :class:`SpecError` when the query has
    error-severity findings (the PR-7 taxonomy path: CLI exits 2, the
    server answers 400 — both with the findings attached)."""
    errs = [f for f in lint_query(q) if f.severity == "error"]
    if errs:
        from ..resilience.errors import SpecError
        raise SpecError(
            f"query fails static lint: {errs[0].message}"
            + (f" (+{len(errs) - 1} more)" if len(errs) > 1 else ""),
            field=errs[0].code,
            findings=[f.to_json() for f in errs])


def errors_only(findings: Iterable[Finding]) -> list[Finding]:
    return [f for f in findings if f.severity == "error"]
