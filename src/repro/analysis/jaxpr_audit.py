"""Static audit of every universal executable family the engine builds.

The engine's whole performance story rests on a handful of jitted
"universal" executables (one per (op-class, level-count) family —
``mapspace.universal``, the netspace shape-as-operand variant, the
co-DSE hardware tail).  This auditor traces each of them with
``jax.make_jaxpr`` — tracing only, no XLA compile — and asserts the
invariants the engine's numerics and compile budget depend on:

``JAX-F64``
    no 64-bit array appears anywhere in the trace (the evaluator is
    float32 end-to-end; one stray Python float in the wrong place turns
    the whole pipeline f64 under x64 mode);
``JAX-WIDEN``
    no silent ``convert_element_type`` widening within a kind (f32→f64,
    i32→i64) — the classic source of accidental precision/cost creep;
``JAX-CALLBACK``
    no host callback primitive on the hot path (a ``pure_callback``
    would serialize every chunk through Python);
``JAX-WEAKTYPE``
    no weakly-typed output aval (a weak-type leak means some retrace
    will specialize differently on the next Python scalar and recompile);
``JAX-CONSTFOLD``
    every operand array is actually *used* by the traced computation —
    an ignored operand means a value that should be vmapped got baked in
    as a static constant, i.e. a recompile per value;
``JAX-DONATION``
    the fused evaluate-and-reduce tail shrinks: total output bytes stay
    under half the input bytes, so the donated operand buffer genuinely
    covers the result and chunk memory stays O(block);
``JAX-PRIMBUDGET``
    the traced primitive count per family stays under a checked-in
    budget (``PRIMITIVE_BUDGET``), the compile-time analog of the
    BENCH_mapspace compile-seconds budget;
``JAX-TRACE``
    the family traces at all (a trace error is itself a finding, not a
    crash).

The audit corpus mirrors what CI actually compiles: a small conv2d and
a gemm, 1-level and 2-level specs, in plain / reduced / co-DSE /
netspace(ext-operand) variants, at 1 and ``jax.local_device_count()``
devices (the pmap path).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from .findings import Finding

# Traced-primitive budget per audit family, measured over every variant
# in the corpus (max: conv:L1 3883, conv:L2 13299, gemm:L1 1201,
# gemm:L2 2695) with ~50% headroom.  A budget miss means an engine
# change materially grew the program XLA must optimize — raise the
# budget consciously, in review, like the compile-seconds budget in
# BENCH_mapspace.
PRIMITIVE_BUDGET = {
    "audit-conv:L1": 5800,
    "audit-conv:L2": 20000,
    "audit-gemm:L1": 1800,
    "audit-gemm:L2": 4100,
}
# Fallback for families outside the checked-in corpus (custom audits).
_DEFAULT_BUDGET = {"L1": 6000, "L2": 20000}

_WIDTHS = {"float64", "int64", "uint64", "complex128"}


def _budget_for(family: str) -> int:
    return PRIMITIVE_BUDGET.get(
        family, _DEFAULT_BUDGET["L2" if family.endswith(":L2") else "L1"])


@dataclasses.dataclass(frozen=True)
class FamilyCase:
    """One traced executable: the wrapped (jit/pmap) callable, its
    operand pytree, and — when the unused-operand check applies — the
    unwrapped vmap composition the jit would hide."""
    name: str                     # e.g. "audit-conv:L2/codse"
    family: str                   # family label, e.g. "audit-conv:L2"
    fn: Callable
    ops: dict[str, np.ndarray]
    kind: str                     # plain | reduced | codse | netspace
    unwrapped: Callable | None = None
    unwrapped_ops: dict[str, np.ndarray] | None = None
    # operands the unused-operand check tolerates: a one-hot over ONE
    # cluster candidate carries no information, so the evaluator
    # rightly drops it at trace time — that is not a recompile hazard
    allow_unused: tuple[str, ...] = ()


# ----------------------------------------------------------------------
# Corpus: the families CI compiles, at trace-only cost
# ----------------------------------------------------------------------

def _audit_ops():
    from ..core.tensor_analysis import conv2d, gemm
    return [conv2d("audit-conv", k=8, c=6, y=10, x=10, r=3, s=3),
            gemm("audit-gemm", m=32, n=64, k=64)]


def _points(space, *, cluster: bool, n: int) -> list[tuple]:
    """n valid points of one level-count family (minimum tiles)."""
    cs = [i for i, c in enumerate(space.cluster_options)
          if (c is not None) == cluster]
    base = (0,) * len(space.axes)
    pts = [(s, p, c) + base
           for s in range(len(space.spatial_choices))
           for p in range(len(space.perms))
           for c in cs]
    return (pts * (n // len(pts) + 1))[:n]


def _with_live(ops: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    n = len(ops["pes"])
    return dict(ops, live=np.ones((n,), np.float32))


def _ext_ops(op, spec, ops: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Extend base operands with the netspace shape-as-operand columns."""
    n = len(ops["pes"])
    ext = np.asarray([op.dims[d] for d in spec.dim_names], np.float32)
    out = dict(ops, ext=np.tile(ext, (n, 1)))
    if spec.cluster:
        out["cin_size"] = np.tile(np.asarray(
            [c[1] for c in spec.cluster], np.float32), (n, 1))
        out["cin_off"] = np.tile(np.asarray(
            [c[2] for c in spec.cluster], np.float32), (n, 1))
    return out


def _shard(ops: dict[str, np.ndarray], nd: int) -> dict[str, np.ndarray]:
    """Add the leading device axis the pmap executable expects (the
    1-device executable is a jit and takes the flat batch as-is)."""
    if nd <= 1:
        return ops
    return {k: v.reshape((nd, len(v) // nd) + v.shape[1:])
            for k, v in ops.items()}


def _unwrapped_reduced(op, spec, reduce):
    """The exact composition ``_build_reduced`` jits — traced bare so the
    jaxpr's invars line up 1:1 with the operand dict and an ignored
    operand is visible (jit would still thread it through the pjit eqn)."""
    import jax
    from ..core.vectorized import _reduce_tail, _universal_eval_one
    hw_static = dict(noc_latency=2.0, multicast=True,
                     spatial_reduction=True, macs_per_pe=1)
    eval_one = _universal_eval_one(op, spec, hw_static)

    def chunk_fn(ops):
        feats = jax.vmap(eval_one)(
            {k: v for k, v in ops.items() if k != "live"})
        return _reduce_tail(reduce, feats, ops)

    return chunk_fn


def build_cases(n_devices: int = 1) -> list[FamilyCase]:
    """The audit corpus at one device count.  ``n_devices > 1`` builds
    the pmap variants of the reduced executables (the plain/unwrapped
    single-shard cases are device-count independent)."""
    from ..core.dse import DSEConfig
    from ..core.vectorized import (HWTail, ReduceSpec,
                                   universal_evaluator,
                                   universal_reduced_evaluator)
    from ..mapspace.space import build_space
    from ..mapspace.universal import encode_points, universal_specs

    # large enough that the O(n) terms of the donation-shrink check
    # dominate the O(k) top-k constants, as they do at real block sizes
    n = 256
    n -= n % n_devices
    cfg = DSEConfig()
    reduce = ReduceSpec(objective="edp", k=4)
    codse = dataclasses.replace(reduce, hw=HWTail(
        area_power=cfg.area_power, area_budget_mm2=cfg.area_budget_mm2,
        power_budget_mw=cfg.power_budget_mw))
    net_reduce = ReduceSpec(objective="runtime", k=1, pareto=False,
                            cols=("runtime", "energy_pj", "l1_kb", "l2_kb"))
    cases: list[FamilyCase] = []
    for op in _audit_ops():
        space = build_space(op)
        for spec in universal_specs(op, space):
            if spec is None:
                continue
            fam = f"{op.name}:L{2 if spec.cluster else 1}"
            pts = _points(space, cluster=bool(spec.cluster), n=n)
            base = encode_points(op, space, pts, spec,
                                 num_pes=64, noc_bw=32.0)
            live = _with_live(base)
            sharded = _shard(live, n_devices)
            nspec = dataclasses.replace(spec, ext_operand=True)
            nops = _shard(_with_live(_ext_ops(op, nspec, base)), n_devices)
            tolerate = ("csel",) if len(spec.cluster) == 1 else ()

            if n_devices == 1:
                cases.append(FamilyCase(
                    name=f"{fam}/plain", family=fam, kind="plain",
                    fn=universal_evaluator(op, spec), ops=base))
            for kind, rspec, fops in (("reduced", reduce, sharded),
                                      ("codse", codse, sharded)):
                cases.append(FamilyCase(
                    name=f"{fam}/{kind}" + (f"@{n_devices}dev"
                                            if n_devices > 1 else ""),
                    family=fam, kind=kind,
                    fn=universal_reduced_evaluator(
                        op, spec, rspec, n_devices=n_devices),
                    ops=fops,
                    unwrapped=_unwrapped_reduced(op, spec, rspec),
                    unwrapped_ops=live, allow_unused=tolerate))
            cases.append(FamilyCase(
                name=f"{fam}/netspace" + (f"@{n_devices}dev"
                                          if n_devices > 1 else ""),
                family=fam, kind="netspace",
                fn=universal_reduced_evaluator(
                    op, nspec, net_reduce, n_devices=n_devices),
                ops=nops,
                unwrapped=_unwrapped_reduced(op, nspec, net_reduce),
                unwrapped_ops=_with_live(_ext_ops(op, nspec, base)),
                allow_unused=tolerate))
    return cases


# ----------------------------------------------------------------------
# Jaxpr checks
# ----------------------------------------------------------------------

def _sub_jaxprs(params: dict):
    import jax
    for v in params.values():
        if isinstance(v, jax.core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jax.core.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for w in v:
                if isinstance(w, jax.core.ClosedJaxpr):
                    yield w.jaxpr
                elif isinstance(w, jax.core.Jaxpr):
                    yield w


def _walk_eqns(jaxpr):
    """Every eqn of a jaxpr and its nested sub-jaxprs (pjit bodies, pmap
    call_jaxprs, scan/cond branches)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from _walk_eqns(sub)


def _dtype_of(v) -> Any:
    aval = getattr(v, "aval", None)
    return getattr(aval, "dtype", None)


def audit_case(case: FamilyCase) -> tuple[list[Finding], int]:
    """Findings + traced primitive count for one executable family
    variant."""
    import jax
    site = f"jaxpr::{case.name}"
    findings: list[Finding] = []

    def f(code: str, msg: str, severity: str = "error") -> None:
        findings.append(Finding(code=code, site=site, analyzer="jaxpr",
                                message=msg, severity=severity))

    try:
        closed = jax.make_jaxpr(case.fn)(case.ops)
    except Exception as e:                        # noqa: BLE001
        f("JAX-TRACE", f"{type(e).__name__}: {e}")
        return findings, 0

    n_prims = 0
    seen_f64: set[str] = set()
    seen_cb: set[str] = set()
    seen_widen: set[str] = set()
    for eqn in _walk_eqns(closed.jaxpr):
        n_prims += 1
        pname = eqn.primitive.name
        if "callback" in pname or "outside_call" in pname:
            seen_cb.add(pname)
        for v in eqn.outvars:
            dt = _dtype_of(v)
            if dt is not None and dt.name in _WIDTHS:
                seen_f64.add(f"{pname} -> {dt.name}")
        if pname == "convert_element_type":
            src = _dtype_of(eqn.invars[0])
            dst = eqn.params.get("new_dtype")
            if src is not None and dst is not None \
                    and np.dtype(dst).kind == np.dtype(src).kind \
                    and np.dtype(dst).itemsize > np.dtype(src).itemsize:
                seen_widen.add(f"{np.dtype(src).name} -> "
                               f"{np.dtype(dst).name}")
    for what in sorted(seen_f64):
        f("JAX-F64", f"64-bit value in the traced program: {what}")
    for what in sorted(seen_widen):
        f("JAX-WIDEN", f"silent convert_element_type widening: {what}")
    for what in sorted(seen_cb):
        f("JAX-CALLBACK", f"host callback on the hot path: {what}")
    for aval in closed.out_avals:
        leaves = aval if isinstance(aval, (tuple, list)) else [aval]
        for a in leaves:
            if getattr(a, "weak_type", False):
                f("JAX-WEAKTYPE",
                  f"weakly-typed output aval {a}: the next Python "
                  f"scalar retrace will recompile")

    budget = _budget_for(case.family)
    if n_prims > budget:
        f("JAX-PRIMBUDGET",
          f"{n_prims} traced primitives exceeds the "
          f"{case.family.split(':')[-1]} budget of {budget}")

    if case.unwrapped is not None:
        findings += _audit_unwrapped(case)
    if case.kind in ("reduced", "codse", "netspace"):
        findings += _audit_shrink(case, closed, site)
    return findings, n_prims


def _audit_unwrapped(case: FamilyCase) -> list[Finding]:
    """JAX-CONSTFOLD: trace the bare vmap composition and demand every
    operand leaf is consumed.  Dict pytrees flatten in sorted-key order,
    so jaxpr.invars line up with sorted(ops)."""
    import jax
    site = f"jaxpr::{case.name}"
    ops = case.unwrapped_ops or case.ops
    try:
        closed = jax.make_jaxpr(case.unwrapped)(ops)
    except Exception as e:                        # noqa: BLE001
        return [Finding(code="JAX-TRACE", site=site, analyzer="jaxpr",
                        message=f"unwrapped trace failed: "
                                f"{type(e).__name__}: {e}")]
    used: set[int] = set()

    def mark(jaxpr):
        for eqn in jaxpr.eqns:
            for v in eqn.invars:
                if not isinstance(v, jax.core.Literal):
                    used.add(id(v))
            for sub in _sub_jaxprs(eqn.params):
                mark(sub)
        for v in jaxpr.outvars:
            if not isinstance(v, jax.core.Literal):
                used.add(id(v))

    mark(closed.jaxpr)
    findings = []
    keys = sorted(ops)
    for key, var in zip(keys, closed.jaxpr.invars):
        if key in case.allow_unused:
            continue
        if id(var) not in used:
            findings.append(Finding(
                code="JAX-CONSTFOLD", site=site, analyzer="jaxpr",
                message=f"operand {key!r} is ignored by the traced "
                        f"computation — its value must be baked in "
                        f"statically, a recompile per distinct value"))
    return findings


def _aval_bytes(avals) -> int:
    total = 0
    for a in avals:
        shape = getattr(a, "shape", None)
        dt = getattr(a, "dtype", None)
        if shape is None or dt is None:
            continue
        total += int(np.prod(shape, dtype=np.int64)) * np.dtype(dt).itemsize
    return total


def _audit_shrink(case: FamilyCase, closed, site: str) -> list[Finding]:
    """JAX-DONATION: the fused reduce must shrink its input, otherwise
    donating the operand buffer cannot cover the output and chunk memory
    stops being O(block)."""
    in_b = _aval_bytes(closed.in_avals)
    out_b = _aval_bytes(closed.out_avals)
    if out_b * 2 > in_b:
        return [Finding(
            code="JAX-DONATION", site=site, analyzer="jaxpr",
            message=f"reduce tail returns {out_b} B for {in_b} B of "
                    f"operands (> 1/2): the donated buffer no longer "
                    f"covers the result")]
    return []


# ----------------------------------------------------------------------
# Entry point
# ----------------------------------------------------------------------

def audit(device_counts: tuple[int, ...] = (1,)
          ) -> tuple[list[Finding], dict[str, Any]]:
    """Run the full audit.  Returns ``(findings, report)`` where the
    report carries per-case traced primitive counts and the budget —
    the exact payload BENCH_mapspace embeds next to the compile
    budget."""
    findings: list[Finding] = []
    prim_counts: dict[str, int] = {}
    for nd in device_counts:
        for case in build_cases(nd):
            fs, n = audit_case(case)
            findings += fs
            prim_counts[case.name] = n
    report = {
        "primitive_counts": prim_counts,
        "primitive_budget": dict(PRIMITIVE_BUDGET),
        "device_counts": list(device_counts),
        "n_cases": len(prim_counts),
    }
    return findings, report
