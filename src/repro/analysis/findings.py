"""The shared finding/waiver schema of the static analyzers.

Every analyzer (``jaxpr_audit``, ``concurrency``, ``speclint``) reports
:class:`Finding` rows with a registered code; ``repro.launch.lint`` and
the CI gate consume them uniformly.  Intentional exceptions live in a
checked-in ``waivers.toml`` next to this module — each waiver names the
(code, site) pair it excuses plus a one-line justification, and a waiver
that matches no finding FAILS the lint (stale waivers rot into blind
spots; CI forces their removal the moment the underlying code is fixed).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Iterable, Sequence

try:                                  # stdlib on 3.11+ (the CI floor)
    import tomllib
except ModuleNotFoundError:           # 3.10: the vendored shim
    import tomli as tomllib  # type: ignore[no-redef]

# ----------------------------------------------------------------------
# Finding codes — the registry the README documents
# ----------------------------------------------------------------------

CODES: dict[str, str] = {
    # jaxpr auditor (analysis/jaxpr_audit.py)
    "JAX-F64": "float64/complex128 aval inside a hot-path executable",
    "JAX-WIDEN": "convert_element_type widens a floating dtype",
    "JAX-CALLBACK": "host callback primitive on the hot path",
    "JAX-WEAKTYPE": "weak-typed output aval (recompile hazard)",
    "JAX-CONSTFOLD": "operand unused in the jaxpr — constant-folded "
                     "instead of vmapped (recompile hazard)",
    "JAX-DONATION": "reduction tail does not shrink its inputs, so "
                    "donated operand buffers cannot be consumed",
    "JAX-PRIMBUDGET": "per-family jaxpr primitive count over budget",
    "JAX-TRACE": "family failed to trace at all",
    # concurrency linter (analysis/concurrency.py)
    "CONC-UNLOCKED": "shared attribute mutated outside the owning "
                     "lock/condition in a threaded module",
    "CONC-GLOBAL": "module-global rebound from a function in a "
                   "threaded module",
    "CONC-CONTEXTVAR": "ContextVar.set() without a matching reset()",
    "CONC-THREADLOCAL": "threading.local() built inside a function "
                        "(new storage per call, not per thread)",
    # spec/dataflow linter (analysis/speclint.py)
    "SPEC-PARSE": "dataflow program fails structural validation",
    "SPEC-ILLEGAL": "directive size/offset illegal for the layer dims",
    "SPEC-TILE": "steady temporal tile does not divide its dim extent "
                 "(edge phases; off the divisor-exact fast path)",
    "SPEC-CLUSTER": "cluster level illegal (empty inner level, or size "
                    "exceeds the PE array)",
    "SPEC-SPATIAL": "multiple SpatialMaps at one level are not aligned "
                    "(unequal sizes)",
    "SPEC-DIMS": "searched dim is not a (searchable) dim of the op",
    "SPEC-SPACE": "no legal mapping space for the query spec",
    "SPEC-BUDGET": "every mapping's working-set lower bound exceeds "
                   "the configured buffer budget (statically "
                   "infeasible search)",
}

SEVERITIES = ("error", "warn")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One static-analysis result.

    ``site`` is the stable waiver anchor (``module.py::Class.method`` or
    an analyzer-defined equivalent — never a line number, so findings
    survive unrelated edits); ``where`` carries the precise location for
    humans."""
    code: str
    site: str
    message: str
    severity: str = "error"
    analyzer: str = ""
    where: str = ""

    def __post_init__(self) -> None:
        if self.code not in CODES:
            raise ValueError(f"unregistered finding code {self.code!r}")
        if self.severity not in SEVERITIES:
            raise ValueError(f"bad severity {self.severity!r}")

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    def one_line(self) -> str:
        loc = self.where or self.site
        return f"{self.code} [{self.severity}] {loc}: {self.message}"


@dataclasses.dataclass(frozen=True)
class Waiver:
    """One intentional exception: excuses every finding whose (code,
    site) matches.  ``reason`` is mandatory — a waiver without a
    justification is a finding in itself."""
    code: str
    site: str
    reason: str

    def __post_init__(self) -> None:
        if self.code not in CODES:
            raise ValueError(f"waiver for unregistered code {self.code!r}")
        if not self.reason.strip():
            raise ValueError(f"waiver {self.code}@{self.site} needs a "
                             f"non-empty reason")

    def matches(self, f: Finding) -> bool:
        return f.code == self.code and f.site == self.site

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


DEFAULT_WAIVERS = os.path.join(os.path.dirname(__file__), "waivers.toml")


def load_waivers(path: str | None = None) -> list[Waiver]:
    """Parse ``waivers.toml`` (``[[waiver]]`` tables with ``code``,
    ``site``, ``reason``)."""
    path = path or DEFAULT_WAIVERS
    if not os.path.exists(path):
        return []
    with open(path, "rb") as f:
        doc = tomllib.load(f)
    out = []
    for row in doc.get("waiver", []):
        out.append(Waiver(code=row["code"], site=row["site"],
                          reason=row["reason"]))
    return out


def apply_waivers(findings: Sequence[Finding],
                  waivers: Iterable[Waiver]
                  ) -> tuple[list[Finding], list[Finding], list[Waiver]]:
    """Split findings into (unwaived, waived) and return the waivers
    that matched nothing — unused waivers fail CI (see module doc)."""
    waivers = list(waivers)
    used: set[int] = set()
    unwaived: list[Finding] = []
    waived: list[Finding] = []
    for f in findings:
        hit = False
        for i, w in enumerate(waivers):
            if w.matches(f):
                used.add(i)
                hit = True
        (waived if hit else unwaived).append(f)
    unused = [w for i, w in enumerate(waivers) if i not in used]
    return unwaived, waived, unused


def sort_findings(findings: Iterable[Finding]) -> list[Finding]:
    """Stable report order: errors first, then by site/code."""
    return sorted(findings,
                  key=lambda f: (SEVERITIES.index(f.severity),
                                 f.site, f.code, f.message))
