"""repro.analysis — static verification of engine, concurrency, and
dataflow-spec invariants.

Three analyzers share one :class:`Finding`/:class:`Waiver` schema and
one CLI (``python -m repro.launch.lint``):

* :mod:`repro.analysis.concurrency` — AST linter over ``src/repro/``
  for unlocked shared-state mutation in the threaded modules;
* :mod:`repro.analysis.speclint` — static legality of dataflow programs
  and ``Query`` specs before any compile;
* :mod:`repro.analysis.jaxpr_audit` — jaxpr-level invariants of every
  universal executable family (f64, callbacks, const-folded operands,
  donation shrink, primitive budget).

``run_repo_lint`` is the cheap, jax-free pass (concurrency + shipped
dataflow corpus); ``run_full`` adds the jaxpr audit.  Both return raw
findings — apply ``load_waivers``/``apply_waivers`` to honour the
checked-in ``waivers.toml``.
"""
from __future__ import annotations

from typing import Any

from .findings import (CODES, DEFAULT_WAIVERS, Finding, Waiver,
                       apply_waivers, load_waivers, sort_findings)

__all__ = ["CODES", "DEFAULT_WAIVERS", "Finding", "Waiver",
           "apply_waivers", "load_waivers", "run_full", "run_repo_lint",
           "sort_findings"]


def run_repo_lint() -> list[Finding]:
    """The jax-free analyzers: concurrency lint over the source tree +
    legality lint over the shipped dataflow corpus."""
    from . import concurrency, speclint
    return sort_findings(concurrency.lint_tree() + speclint.lint_corpus())


def run_full(device_counts: tuple[int, ...] = (1,)
             ) -> tuple[list[Finding], dict[str, Any]]:
    """Everything: repo lint + the jaxpr audit.  Returns the findings
    and the audit's primitive-count report."""
    from . import jaxpr_audit
    findings = run_repo_lint()
    audit_findings, report = jaxpr_audit.audit(device_counts)
    return sort_findings(findings + audit_findings), report
