"""AdamW with fp32 master/moment states sharded identically to the params
(ZeRO-style: states inherit the FSDP/TP PartitionSpecs of their weights).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step) -> jnp.ndarray:
    """Linear warmup + cosine decay."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params) -> dict:
    def zeros_like_f32(p):
        return jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros_like_f32, params),
        "nu": jax.tree.map(zeros_like_f32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                        for l in leaves))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu2 = cfg.b1 * mu + (1 - cfg.b1) * g
        nu2 = cfg.b2 * nu + (1 - cfg.b2) * g * g
        step_ = (mu2 / b1c) / (jnp.sqrt(nu2 / b2c) + cfg.eps)
        step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * step_
        return p2.astype(p.dtype), mu2, nu2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        **state,  # preserve extra slots (e.g. compression error feedback)
        "mu": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "nu": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
