from .pipeline import SyntheticLMDataset, batch_for_step

__all__ = ["SyntheticLMDataset", "batch_for_step"]
