"""Deterministic, shardable synthetic LM data pipeline.

Tokens are a stateless function of (step, position) — any host can
materialize exactly its shard for any step, which makes the pipeline
trivially elastic (restore on a different host count reproduces the same
global batch) and checkpoint-free (only the step index needs saving).

The stream is a Zipf-ish mixture with local n-gram structure so models
actually have something to learn in examples/train_lm.py.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _tokens(step: int, global_batch: int, seq: int, vocab: int,
            seed: int) -> np.ndarray:
    """Tokens for the FULL global batch — a pure function of (step, seed),
    independent of how it is later sliced (shard invariance)."""
    rng = np.random.Generator(np.random.Philox(key=seed * 1_000_003 + step))
    # per-row base offset gives each sequence its own "topic"
    base = rng.integers(0, vocab, size=(global_batch, 1))
    noise = rng.integers(0, vocab, size=(global_batch, seq))
    ar = np.cumsum(rng.integers(0, 7, size=(global_batch, seq)), axis=1)
    toks = (base + ar + (noise % 13)) % vocab
    return toks.astype(np.int32)


def batch_for_step(step: int, *, global_batch: int, seq: int, vocab: int,
                   seed: int = 0, shard: tuple[int, int] = (0, 1)) -> dict:
    """Returns this shard's slice of the global batch for ``step``.
    ``shard=(index, count)`` slices the batch dimension; any sharding of
    the same (step, seed) reproduces the same global batch."""
    idx, count = shard
    assert global_batch % count == 0
    rows_per = global_batch // count
    toks = _tokens(step, global_batch, seq + 1, vocab, seed)
    toks = toks[idx * rows_per:(idx + 1) * rows_per]
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass
class SyntheticLMDataset:
    global_batch: int
    seq: int
    vocab: int
    seed: int = 0
    step: int = 0
    shard: tuple[int, int] = (0, 1)

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        b = batch_for_step(self.step, global_batch=self.global_batch,
                           seq=self.seq, vocab=self.vocab, seed=self.seed,
                           shard=self.shard)
        self.step += 1
        return b

    # -- checkpointable state ------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, s: dict) -> None:
        self.step = int(s["step"])
