"""Checkpointing with atomic commits, retention, async save, and *elastic*
restore.

Format: one directory per step containing

    manifest.json          tree structure, shapes, dtypes, step metadata
    <leaf-path>.npy        one file per pytree leaf (full global array)

Writes go to ``<dir>.tmp`` and are committed with an atomic rename, so a
crash mid-save never corrupts the latest checkpoint.  Saves can run on a
background thread (``async_save=True``); ``wait()`` joins.

Elastic restore: leaves are stored as *global* arrays, so a checkpoint
taken on N hosts restores onto any M — the caller reshards by passing
``shardings`` (device placement happens lazily on first use otherwise).
On a real multi-host pod each host would write only its shard plus a
shard index; the manifest format already carries per-leaf shape/dtype so
that extension is purely an I/O change (documented, not needed for the
single-host container).
"""
from __future__ import annotations

import json
import logging
import os
import shutil
import threading
import time
from typing import Any, Callable

import jax
import ml_dtypes
import numpy as np

LOG = logging.getLogger("repro.resilience")

# numpy can't serialize extension dtypes (bfloat16 etc.) natively; store
# them as raw uint16/uint8 views and record the logical dtype in the
# manifest.
_EXT_DTYPES = {
    "bfloat16": (np.uint16, ml_dtypes.bfloat16),
    "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
    "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2),
}


def _flatten(tree, prefix=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], prefix + (str(k),))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            yield from _flatten(v, prefix + (f"_{i}",))
    elif tree is None:
        yield prefix + ("_none",), None
    else:
        yield prefix, tree


def _unflatten_into(skeleton, leaves: dict):
    def rec(node, prefix):
        if isinstance(node, dict):
            return {k: rec(v, prefix + (str(k),))
                    for k, v in sorted(node.items())}
        if isinstance(node, (tuple, list)):
            out = [rec(v, prefix + (f"_{i}",)) for i, v in enumerate(node)]
            return type(node)(out)
        if node is None:
            return None
        return leaves["/".join(prefix)]
    return rec(skeleton, ())


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: dict | None = None,
             async_save: bool = False) -> None:
        # materialize on host *before* backgrounding (snapshot semantics)
        leaves = []
        for path, leaf in _flatten(tree):
            if leaf is None:
                continue
            leaves.append(("/".join(path), np.asarray(leaf)))
        if async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, leaves, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, leaves, extra or {})

    def _write(self, step: int, leaves, extra: dict) -> None:
        try:
            final = os.path.join(self.directory, f"step_{step:09d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "time": time.time(), "extra": extra,
                        "leaves": {}}
            for name, arr in leaves:
                fn = name.replace("/", "__") + ".npy"
                logical = str(arr.dtype)
                if logical in _EXT_DTYPES:
                    arr = arr.view(_EXT_DTYPES[logical][0])
                np.save(os.path.join(tmp, fn), arr)
                manifest["leaves"][name] = {
                    "file": fn, "shape": list(arr.shape),
                    "dtype": logical}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)        # atomic commit
            self._gc()
        except BaseException as e:  # surfaced by wait()
            self._error = e

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        """Committed steps with a READABLE manifest.  A step directory
        whose manifest is missing or unparsable (e.g. the filesystem ate
        it after the atomic rename) is skipped with a warning, so
        ``latest_step``/``restore`` land on the newest intact
        checkpoint instead of failing."""
        out = []
        for d in os.listdir(self.directory):
            if not d.startswith("step_") or d.endswith(".tmp"):
                continue
            try:
                step = int(d.split("_")[1])
                with open(os.path.join(self.directory, d,
                                       "manifest.json")) as f:
                    json.load(f)
            except (OSError, ValueError):
                LOG.warning("checkpoint %s has no readable manifest — "
                            "skipping it",
                            os.path.join(self.directory, d))
                continue
            out.append(step)
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, skeleton: Any, step: int | None = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``skeleton``.  With ``shardings``
        (a matching tree of NamedSharding), leaves are placed sharded —
        this is the elastic path: the mesh may differ from save time."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves = {}
        for name, meta in manifest["leaves"].items():
            arr = np.load(os.path.join(d, meta["file"]))
            if meta["dtype"] in _EXT_DTYPES:
                arr = arr.view(_EXT_DTYPES[meta["dtype"]][1])
            leaves[name] = arr
        tree = _unflatten_into(skeleton, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if x is not None else x,
                tree, shardings)
        return tree, manifest
