"""Assigned architecture configs (``--arch <id>``).

Each entry is the exact published configuration from the task assignment;
sources are cited per file.  ``REGISTRY[name]`` -> :class:`ModelConfig`.
"""
from __future__ import annotations

from .base import ModelConfig
from .olmo_1b import CONFIG as olmo_1b
from .granite_20b import CONFIG as granite_20b
from .qwen2_72b import CONFIG as qwen2_72b
from .llama3_8b import CONFIG as llama3_8b
from .moonshot_v1_16b_a3b import CONFIG as moonshot_v1_16b_a3b
from .dbrx_132b import CONFIG as dbrx_132b
from .rwkv6_1_6b import CONFIG as rwkv6_1_6b
from .phi_3_vision_4_2b import CONFIG as phi_3_vision_4_2b
from .seamless_m4t_medium import CONFIG as seamless_m4t_medium
from .zamba2_7b import CONFIG as zamba2_7b

REGISTRY: dict[str, ModelConfig] = {
    c.name: c for c in [
        olmo_1b, granite_20b, qwen2_72b, llama3_8b, moonshot_v1_16b_a3b,
        dbrx_132b, rwkv6_1_6b, phi_3_vision_4_2b, seamless_m4t_medium,
        zamba2_7b,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


__all__ = ["ModelConfig", "REGISTRY", "get_config"]
