"""SeamlessM4T-medium [arXiv:2308.11596; hf]: enc-dec, 12L encoder + 12L
decoder interpretation of "12L", d_model=1024 16H (kv=16) d_ff=4096
vocab=256206 — speech frontend is a STUB: input_specs() supplies
precomputed frame embeddings (B, frames, frontend_dim)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_dec_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206,
    norm="ln", mlp_type="gelu", pos="rope",
    frontend="audio", frontend_dim=1024, frontend_len=0,  # len = seq
)
