"""RWKV-6 Finch 1.6B [arXiv:2404.05892; unverified]: 24L d_model=2048
(attention-free), channel-mix d_ff=7168, vocab=65536 — data-dependent decay,
token shift, head size 64."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536,
    ssm_type="rwkv6", ssm_state=64, ssm_head_dim=64, ssm_expand=1,
    norm="ln", pos="none",
)
