"""Model/config system for the assigned architectures.

One frozen dataclass covers all ten families; family-specific fields are
inert elsewhere.  ``reduced()`` derives the CPU smoke-test config (same
family/topology, tiny widths); the full configs are exercised only through
the dry-run (abstract shapes, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int | None = None    # default d_model // n_heads
    qkv_bias: bool = False
    mlp_type: str = "swiglu"       # swiglu | gelu
    norm: str = "rms"              # rms | ln | ln_nonparam
    pos: str = "rope"              # rope | learned | none
    rope_theta: float = 1e4
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_type: str | None = None    # rwkv6 | mamba2
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_width: int = 4
    attn_every: int = 0            # >0: shared attention block cadence

    # encoder-decoder
    n_dec_layers: int = 0          # >0 → enc-dec; n_layers = encoder depth

    # modality frontend stub (precomputed embeddings via input_specs)
    frontend: str | None = None    # vision | audio
    frontend_dim: int = 0
    frontend_len: int = 0

    # numerics / training
    dtype: Any = jnp.bfloat16
    remat: str = "full"            # none | full | dots
    max_learned_pos: int = 8192
    chunk_size: int = 256          # linear-scan / flash block size
    # Fully unroll every internal lax.scan (layers, attention query blocks,
    # recurrence chunks).  Used by the dry-run's *cost* compiles: XLA's
    # cost_analysis counts while-loop bodies once, so exact FLOP/byte/
    # collective totals come from small-depth unrolled compiles that are
    # linearly extrapolated in depth (launch/dryrun.py).
    scan_unroll: bool = False

    # Embedding/head tables are allocated padded to a multiple of this so
    # the vocab dim is tensor-parallel-divisible (e.g. seamless's 256206
    # is not 16-divisible and would replicate a (B,S,V) logits tensor).
    # Logits at pad positions are masked to -inf; published vocab size is
    # unchanged.
    pad_vocab_to: int = 64

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        p = self.pad_vocab_to
        return ((self.vocab + p - 1) // p) * p

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.n_dec_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def ssm_heads(self) -> int:
        return (self.d_model * self.ssm_expand) // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) or 1,
            head_dim=16,
            d_ff=max(32, 128 if not self.n_experts else 32),
            vocab=128,
            max_learned_pos=128,
            chunk_size=16,
        )
        if self.n_experts:
            kw.update(n_experts=min(self.n_experts, 4),
                      top_k=min(self.top_k, 2))
        if self.ssm_type:
            kw.update(ssm_state=16, ssm_head_dim=16, conv_width=2)
        if self.attn_every:
            kw.update(attn_every=2, n_layers=4)
        if self.is_encdec:
            kw.update(n_dec_layers=2)
        if self.frontend:
            kw.update(frontend_dim=32, frontend_len=8)
        return self.replace(**kw)

    # -- parameter accounting (for roofline MODEL_FLOPS) ----------------
    def param_counts(self) -> dict[str, float]:
        d, hd = self.d_model, self.head_dim_
        q = self.n_heads * hd
        kv = self.n_kv_heads * hd
        attn = d * q + 2 * d * kv + q * d
        if self.mlp_type == "swiglu":
            mlp_dense = 3 * d * self.d_ff
        else:
            mlp_dense = 2 * d * self.d_ff
        out: dict[str, float] = {}
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "encdec"):
            layers = self.n_layers + self.n_dec_layers
            cross = self.n_dec_layers * attn
            out["total"] = layers * (attn + mlp_dense) + cross + emb
            out["active"] = out["total"]
        elif self.family == "moe":
            experts = self.n_experts * mlp_dense + \
                self.n_shared_experts * mlp_dense + d * self.n_experts
            act = (self.top_k + self.n_shared_experts) * mlp_dense
            out["total"] = self.n_layers * (attn + experts) + emb
            out["active"] = self.n_layers * (attn + act + d * self.n_experts) + emb
        elif self.family in ("ssm", "hybrid"):
            if self.ssm_type == "rwkv6":
                di = d
                mix = 4 * d * di + di * d + d * 32 * 2  # r,k,v,g,w + out + lora
                ffn = 2 * d * self.d_ff
                per_layer = mix + ffn
            else:  # mamba2
                di = d * self.ssm_expand
                per_layer = d * (2 * di + 2 * self.ssm_heads *
                                 self.ssm_state // max(1, self.ssm_heads) +
                                 self.ssm_heads) + di * d + \
                    2 * self.ssm_state * di
            n_attn = (self.n_layers // self.attn_every) if self.attn_every \
                else 0
            shared = (attn + mlp_dense) if self.attn_every else 0
            out["total"] = self.n_layers * per_layer + shared + emb
            out["active"] = out["total"] if not self.attn_every else \
                self.n_layers * per_layer + n_attn * (attn + mlp_dense) + emb
        else:
            raise ValueError(self.family)
        return out
