"""Phi-3-vision-4.2B [hf:microsoft/Phi-3-vision-128k-instruct; hf]:
32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064 — phi3-mini backbone;
the CLIP frontend is a STUB: input_specs() supplies precomputed patch
embeddings (B, n_patches, frontend_dim)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064,
    norm="rms", mlp_type="swiglu", pos="rope",
    frontend="vision", frontend_dim=1024, frontend_len=576,
)
