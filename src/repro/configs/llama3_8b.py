"""Llama-3-8B [arXiv:2407.21783; unverified]: 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=128256 — RMSNorm, SwiGLU, RoPE theta=500k."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256,
    norm="rms", mlp_type="swiglu", pos="rope", rope_theta=5e5,
)
