"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B; hf]: 48L d_model=2048
16H (kv=16) MoE 64 experts top-6 (+2 shared), expert d_ff=1408,
vocab=163840 — fine-grained DeepSeek-style MoE."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840,
    n_experts=64, top_k=6, n_shared_experts=2,
    norm="rms", mlp_type="swiglu", pos="rope",
)
