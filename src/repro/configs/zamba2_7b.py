"""Zamba2-7B [arXiv:2411.15242; unverified]: 81L d_model=3584 Mamba2
backbone (ssm_state=64, expand=2, head 64) + SHARED attention block
(32H kv=32, d_ff=14336) applied every 6 layers — the shared block reuses
one set of weights at every application (the Zamba trick)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000,
    ssm_type="mamba2", ssm_state=64, ssm_expand=2, ssm_head_dim=64,
    conv_width=4, attn_every=6,
    norm="rms", mlp_type="swiglu", pos="rope",
)
