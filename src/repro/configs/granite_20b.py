"""Granite-20B code model [arXiv:2405.04324; hf]: 52L d_model=6144 48H
(MQA kv=1) d_ff=24576 vocab=49152 — GPT-BigCode style: learned positions,
LayerNorm, GELU MLP, QKV bias."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152,
    norm="ln", mlp_type="gelu", pos="learned", qkv_bias=True,
)
