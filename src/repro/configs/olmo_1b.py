"""OLMo-1B [arXiv:2402.00838; hf]: 16L d_model=2048 16H (kv=16) d_ff=8192
vocab=50304 — non-parametric LN, SwiGLU, RoPE, tied embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=50304,
    norm="ln_nonparam", mlp_type="swiglu", pos="rope", rope_theta=1e4,
    tie_embeddings=True,
)
