"""Logical-axis sharding rules for the production mesh.

Mesh axes: ``(data, model)`` single-pod 16×16; ``(pod, data, model)``
multi-pod 2×16×16.  Logical axes map to mesh axes by the table below; a
mapping is applied only if the dim is divisible by the mesh-axis product,
otherwise trailing→leading axes are dropped (graceful replication — e.g.
seamless's vocab 256206 is not 16-divisible and stays replicated), and a
mesh axis is never used twice in one tensor (first logical axis wins —
e.g. MoE experts take 'model', so the expert FFN's mlp dim replicates).

In MAESTRO vocabulary (core/mapper.py): a mesh axis is a Cluster level, a
logical-axis mapping is a SpatialMap of that tensor dim across the level,
and an unmapped dim is an implicit fully-unrolled TemporalMap.
"""
from __future__ import annotations

from typing import Any, Mapping

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axes (in order)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "embed": ("pod", "data"),      # FSDP/ZeRO-3 weight sharding
    "heads": ("model",),
    "heads_flat": ("model",),
    "kv_heads": ("model",),
    "qkv": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "embed_out": ("model",),
    "layers": (),
    "state": (),
    "conv": (),
    "seq": (),
    "kv_seq": ("data",),           # sequence-sharded KV (long-context)
}


def mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_spec(shape: tuple[int, ...], axes: tuple[str | None, ...],
                 mesh: Mesh, rules: Mapping[str, tuple[str, ...]] | None
                 = None) -> P:
    """Logical axes -> PartitionSpec with divisibility + no-reuse checks."""
    rules = rules or DEFAULT_RULES
    sizes = mesh_axis_sizes(mesh)
    used: set[str] = set()
    parts: list[Any] = []
    for dim, ax in zip(shape, axes):
        if ax is None or ax not in rules:
            parts.append(None)
            continue
        want = [a for a in rules[ax] if a in sizes and a not in used]
        # drop leading axes until the product divides the dim
        assign: tuple[str, ...] = ()
        for start in range(len(want)):
            cand = tuple(want[start:])
            prod = int(np.prod([sizes[a] for a in cand])) if cand else 1
            if cand and dim % prod == 0:
                assign = cand
                break
        if assign:
            used.update(assign)
            parts.append(assign if len(assign) > 1 else assign[0])
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)


def tree_shardings(spec_tree, axes_tree, mesh: Mesh,
                   rules: Mapping[str, tuple[str, ...]] | None = None):
    """Map (ShapeDtypeStruct tree, logical-axes tree) -> NamedSharding tree.

    Manual recursion: the axes tree has *tuples of axis names* as leaves,
    which jax pytrees would wrongly flatten."""
    def rec(spec, axes):
        if _is_axes_leaf(axes):
            return NamedSharding(mesh, resolve_spec(spec.shape, axes, mesh,
                                                    rules))
        if isinstance(axes, dict):
            return {k: rec(spec[k], axes[k]) for k in axes}
        if isinstance(axes, (tuple, list)):
            return type(axes)(rec(s, a) for s, a in zip(spec, axes))
        if axes is None:
            return None
        raise TypeError(f"bad axes node: {axes!r}")
    return rec(spec_tree, axes_tree)


def shardings_for_params(specs, mesh: Mesh, rules=None):
    """From a ParamSpec tree directly."""
    from ..models.param import ParamSpec, map_specs

    def leaf(path, s: ParamSpec):
        return NamedSharding(mesh, resolve_spec(s.shape, s.axes, mesh,
                                                rules))
    return map_specs(specs, leaf)


def batch_sharding(mesh: Mesh, *, shard_batch: bool = True) -> NamedSharding:
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    if not shard_batch or not axes:
        return NamedSharding(mesh, P())
    return NamedSharding(mesh, P(tuple(axes)))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
