"""Activation sharding constraints via an ambient mesh context.

Model code calls ``constrain(x, ("batch", "seq", "vocab"))`` at layout-
critical points (logits, block outputs).  When a mesh context is active
(set by the launch layer around tracing), the logical axes resolve to a
``with_sharding_constraint``; with no context (CPU smoke tests) it is a
no-op.  This is what stops the SPMD partitioner from replicating the
(batch, seq, vocab) logits when the tied embedding's contraction dim and
the batch dim both prefer the 'data' axis.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Mapping

import jax
from jax.sharding import Mesh, NamedSharding

from .sharding import DEFAULT_RULES, resolve_spec

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_mesh_ctx", default=None)


@contextlib.contextmanager
def activate(mesh: Mesh, rules: Mapping[str, tuple[str, ...]] | None = None):
    token = _ACTIVE.set((mesh, rules))
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def active_mesh() -> Mesh | None:
    ctx = _ACTIVE.get()
    return ctx[0] if ctx else None


def constrain(x, axes: tuple[str | None, ...]):
    ctx = _ACTIVE.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = resolve_spec(x.shape, axes, mesh, rules or DEFAULT_RULES)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def wrap_with_context(fn, mesh: Mesh, rules=None):
    """Returns fn that traces under the mesh context."""
    def wrapped(*args, **kw):
        with activate(mesh, rules):
            return fn(*args, **kw)
    return wrapped
