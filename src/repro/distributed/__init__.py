from .sharding import (DEFAULT_RULES, batch_sharding, replicated,
                       resolve_spec, shardings_for_params, tree_shardings)

__all__ = ["DEFAULT_RULES", "batch_sharding", "replicated", "resolve_spec",
           "shardings_for_params", "tree_shardings"]
